//! Threaded TCP server for one KV instance (the Redis role). One instance
//! per simulated node; the store is a mutex-guarded [`Store`] — Redis
//! itself is single-threaded, so serializing commands is faithful.
//!
//! Pipelined clients send several commands before reading any reply, so
//! the connection loop interleaves: it keeps dispatching as long as more
//! request bytes are already buffered and only flushes the reply stream
//! when the input runs dry. A burst of N pipelined commands then costs
//! one reply flush instead of N, and command processing overlaps the
//! client's request serialization.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::kvstore::resp::{self, Value};
use crate::kvstore::store::{Reply, Store};

/// Shared handle to a running server.
pub struct Server {
    addr: std::net::SocketAddr,
    store: Arc<Mutex<Store>>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Total request wire bytes received (network-footprint accounting).
    pub bytes_in: Arc<AtomicU64>,
    /// Total reply wire bytes sent (network-footprint accounting).
    pub bytes_out: Arc<AtomicU64>,
}

impl Server {
    /// Bind and serve on `127.0.0.1:port` (port 0 = ephemeral).
    pub fn start(port: u16) -> std::io::Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let store = Arc::new(Mutex::new(Store::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let bytes_in = Arc::new(AtomicU64::new(0));
        let bytes_out = Arc::new(AtomicU64::new(0));

        let t_store = store.clone();
        let t_stop = stop.clone();
        let t_in = bytes_in.clone();
        let t_out = bytes_out.clone();
        let accept_thread = std::thread::spawn(move || {
            let mut workers: Vec<JoinHandle<()>> = Vec::new();
            for conn in listener.incoming() {
                if t_stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(conn) = conn else { break };
                let store = t_store.clone();
                let stop = t_stop.clone();
                let bin = t_in.clone();
                let bout = t_out.clone();
                workers.push(std::thread::spawn(move || {
                    let _ = serve_conn(conn, store, stop, bin, bout);
                }));
            }
            for w in workers {
                let _ = w.join();
            }
        });

        Ok(Server {
            addr,
            store,
            stop,
            accept_thread: Some(accept_thread),
            bytes_in,
            bytes_out,
        })
    }

    /// The bound listen address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Direct (in-process) access to the store — used by the simulator and
    /// by memory-usage probes, bypassing the socket.
    pub fn store(&self) -> &Arc<Mutex<Store>> {
        &self.store
    }

    /// Memory used by the instance (payload + metadata model).
    pub fn used_memory(&self) -> u64 {
        self.store.lock().unwrap().used_memory()
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(&mut self) {
        if self.accept_thread.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // wake the accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn reply_to_value(r: Reply) -> Value {
    match r {
        Reply::Ok => Value::ok(),
        Reply::Int(i) => Value::Int(i),
        Reply::Bulk(b) => Value::Bulk(b),
        Reply::Null => Value::Null,
        Reply::Multi(vs) => Value::Array(
            vs.into_iter()
                .map(|v| v.map(Value::Bulk).unwrap_or(Value::Null))
                .collect(),
        ),
        Reply::Err(e) => Value::Error(e),
    }
}

fn serve_conn(
    conn: TcpStream,
    store: Arc<Mutex<Store>>,
    stop: Arc<AtomicBool>,
    bytes_in: Arc<AtomicU64>,
    bytes_out: Arc<AtomicU64>,
) -> std::io::Result<()> {
    conn.set_nodelay(true).ok();
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut writer = BufWriter::new(conn);
    while !stop.load(Ordering::SeqCst) {
        let Some(args) = resp::read_command(&mut reader)? else {
            break; // client closed
        };
        // arithmetic wire length — no clones on the request path
        let mut in_len: u64 = 1 + args.len().to_string().len() as u64 + 2;
        for a in &args {
            in_len += 1 + a.len().to_string().len() as u64 + 2 + a.len() as u64 + 2;
        }
        bytes_in.fetch_add(in_len, Ordering::Relaxed);
        let reply = {
            let mut s = store.lock().unwrap();
            s.dispatch(&args)
        };
        let v = reply_to_value(reply);
        bytes_out.fetch_add(v.wire_len(), Ordering::Relaxed);
        resp::write_value(&mut writer, &v)?;
        // Flush only when no further pipelined request bytes are already
        // buffered: anything still in `reader`'s buffer was fully sent by
        // the client before it started waiting, so delaying the flush
        // cannot deadlock and batches replies for the whole burst.
        if reader.buffer().is_empty() {
            writer.flush()?;
        }
    }
    Ok(())
}

//! The distributed in-memory data store system (the Redis role in the
//! paper): RESP protocol, store with memory accounting and `MGETSUFFIX`,
//! the reusable RESP service layer and the threaded TCP servers built on
//! it (the KV store and the sealed-index query tier), pipelined client,
//! mod-N sharding, the flat [`batch::SuffixBatch`] arenas the zero-copy
//! fetch path runs on, and the reducer-side suffix prefetcher.

pub mod batch;
pub mod client;
pub mod prefetch;
pub mod query;
pub mod resp;
pub mod server;
pub mod service;
pub mod shard;
pub mod store;

use std::net::SocketAddr;

use crate::kvstore::server::Server;
use crate::kvstore::shard::ShardedClient;

/// A bundle of local KV instances on ephemeral ports — one per simulated
/// node — plus a connected sharded client. The real-TCP backend of the
/// example pipelines and integration tests.
pub struct LocalKvCluster {
    /// The running instances (one per simulated node).
    pub servers: Vec<Server>,
}

impl LocalKvCluster {
    /// Start `n_instances` servers on ephemeral loopback ports.
    pub fn start(n_instances: usize) -> std::io::Result<Self> {
        Self::start_with_faults(n_instances, None)
    }

    /// [`LocalKvCluster::start`] under a fault-injection plan: server `i`
    /// serves as shard `i` of the plan, so its kill/revive schedule and
    /// reply delay apply to exactly the shard the plan names.
    pub fn start_with_faults(
        n_instances: usize,
        faults: Option<std::sync::Arc<crate::faults::FaultPlan>>,
    ) -> std::io::Result<Self> {
        let servers = (0..n_instances)
            .map(|i| Server::start_with_faults(0, i, faults.clone()))
            .collect::<std::io::Result<Vec<_>>>()?;
        Ok(Self { servers })
    }

    /// Listen addresses, one per instance, in shard order.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.servers.iter().map(|s| s.addr()).collect()
    }

    /// A fresh sharded client connected to every instance.
    pub fn client(&self) -> crate::kvstore::client::Result<ShardedClient> {
        ShardedClient::connect(&self.addrs())
    }

    /// Total memory used across instances (paper's "donated" memory).
    pub fn used_memory(&self) -> u64 {
        self.servers.iter().map(|s| s.used_memory()).sum()
    }

    /// Server-side wire traffic totals (in, out).
    pub fn traffic(&self) -> (u64, u64) {
        use std::sync::atomic::Ordering;
        let mut t = (0, 0);
        for s in &self.servers {
            t.0 += s.bytes_in.load(Ordering::Relaxed);
            t.1 += s.bytes_out.load(Ordering::Relaxed);
        }
        t
    }
}

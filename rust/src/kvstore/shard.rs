//! Sharding across KV instances, and the `SuffixStore` abstraction the
//! scheme pipeline programs against.
//!
//! Routing is the paper's: `sequence_number mod n_instances` (§IV-A),
//! one instance per node. Reads are stored under their decimal sequence
//! number; suffixes are fetched in bulk with `MGETSUFFIX`, grouped per
//! instance to aggregate round trips (§IV-B).
//!
//! Shards are independent instances, so both directions of bulk traffic
//! run one windowed pipeline per shard *concurrently*: every shard keeps
//! its own batched commands in flight while the others do the same,
//! instead of draining one instance at a time. The sequential variants
//! ([`ShardedClient::fetch_suffixes_sequential`]) issue byte-identical
//! commands without any overlap — they exist as the baseline for the
//! pipelining benchmarks and equivalence tests.

use std::net::SocketAddr;

use crate::kvstore::batch::SuffixBatch;
use crate::kvstore::client::{Client, FailoverConfig, KvError, Result};
use crate::kvstore::resp::{self, Value};
use crate::kvstore::store::Store;
use crate::suffix::encode::unpack_index;
use crate::suffix::reads::Read;
use crate::util::bytes::{dec_len, fmt_dec};

/// Wire traffic (client side) for the footprint ledger.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Traffic {
    /// Request bytes written.
    pub sent: u64,
    /// Reply bytes read.
    pub received: u64,
}

impl Traffic {
    /// Both directions combined.
    pub fn total(&self) -> u64 {
        self.sent + self.received
    }
}

/// What the scheme needs from the in-memory data store system. Both
/// bulk methods return the wire traffic they caused, so callers can
/// charge the footprint ledger per phase (KvPut vs KvFetch).
pub trait SuffixStore: Send {
    /// Store reads (aggregated per instance, batched).
    fn put_reads(&mut self, reads: &[Read]) -> Result<Traffic>;
    /// Fetch suffix code bytes (terminator NOT included) for packed
    /// indexes, in request order — the original `Vec`-of-`Vec`s path,
    /// kept as the baseline the arena path is equivalence-tested against.
    fn fetch_suffixes(&mut self, indexes: &[i64]) -> Result<(Vec<Vec<u8>>, Traffic)>;
    /// Zero-copy fetch: append one entry per index (request order) into
    /// `out`'s flat arena. Wire bytes, reply bytes, and ledger traffic
    /// are identical to [`SuffixStore::fetch_suffixes`]; only the
    /// destination changes. A missing read is an error, as in the `Vec`
    /// path; on error `out`'s appended contents are unspecified.
    ///
    /// The default adapts via `fetch_suffixes` (one copy per suffix);
    /// real stores override it with a genuinely flat path.
    fn fetch_suffixes_into(&mut self, indexes: &[i64], out: &mut SuffixBatch) -> Result<Traffic> {
        let (texts, traffic) = self.fetch_suffixes(indexes)?;
        for t in &texts {
            out.push(t);
        }
        Ok(traffic)
    }
    /// Client-side wire traffic so far.
    fn traffic(&self) -> Traffic;
    /// Total memory used by all instances (payload + metadata).
    fn used_memory(&mut self) -> u64;
    /// Number of instances (shards).
    fn n_shards(&self) -> usize;
    /// Key/value pairs per batched put command (§IV-B aggregation knob,
    /// `SchemeConfig::put_batch`). Implementations without a wire format
    /// may ignore it.
    fn set_put_batch(&mut self, pairs: usize) {
        let _ = pairs;
    }
}

/// How many key/value (or key/offset) pairs go into one batched command.
/// 2048 measured ~15%% faster than 512 over loopback TCP (fewer round
/// trips; §Perf iteration 4) while keeping commands well under Redis-like
/// request-size limits.
pub const BATCH_PAIRS: usize = 2048;

fn key_of(seq: u64) -> Vec<u8> {
    seq.to_string().into_bytes()
}

/// Run one closure per (client, per-shard request) pair, concurrently
/// when real cores exist; on a single-CPU host the extra threads are
/// pure context-switch overhead, so go sequential (§Perf iteration 5).
/// Requests are handed out `&mut` so a shard can fill per-shard state
/// (the arena fetch path's reply batches) in place. Shards whose
/// `skip(req)` is true (empty request lists — common in index-only mode
/// where a tie-break plan touches few shards) yield `Ok(T::default())`
/// without spawning a thread.
fn for_each_shard<R, T>(
    clients: &mut [Client],
    reqs: &mut [R],
    skip: impl Fn(&R) -> bool + Sync,
    f: impl Fn(&mut Client, &mut R) -> Result<T> + Sync,
) -> Vec<Result<T>>
where
    R: Send,
    T: Default + Send,
{
    static PARALLEL: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    let parallel = *PARALLEL.get_or_init(|| {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) > 1
    });
    if parallel {
        let f = &f;
        let mut results = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = clients
                .iter_mut()
                .zip(reqs.iter_mut())
                .map(|(client, req)| {
                    if skip(req) {
                        None
                    } else {
                        Some(scope.spawn(move || f(client, req)))
                    }
                })
                .collect();
            results = handles
                .into_iter()
                .map(|h| match h {
                    Some(h) => h.join().expect("shard thread"),
                    None => Ok(T::default()),
                })
                .collect();
        });
        results
    } else {
        clients
            .iter_mut()
            .zip(reqs.iter_mut())
            .map(|(c, r)| if skip(r) { Ok(T::default()) } else { f(c, r) })
            .collect()
    }
}

// ---------------------------------------------------------------------
// TCP-backed sharded store (real servers, real sockets)
// ---------------------------------------------------------------------

/// One [`Client`] per KV instance, with mod-N routing and concurrent
/// per-shard pipelines for bulk puts and fetches.
pub struct ShardedClient {
    clients: Vec<Client>,
    put_batch: usize,
    /// Reusable per-shard fetch plan + reply arenas for the zero-copy
    /// path: after warm-up, a steady-state `fetch_suffixes_into` call
    /// allocates nothing here.
    plan: Vec<ShardPlan>,
}

/// One shard's slice of an arena fetch: which request positions route to
/// it, the (seq, offset) pairs to ask for, and the reply arena its
/// pipeline streams into.
#[derive(Default)]
struct ShardPlan {
    positions: Vec<usize>,
    reqs: Vec<(u64, usize)>,
    arena: SuffixBatch,
}

impl ShardedClient {
    /// Connect one client per instance address with the default
    /// failover policy.
    pub fn connect(addrs: &[SocketAddr]) -> Result<Self> {
        Self::connect_with(addrs, FailoverConfig::default())
    }

    /// Connect one client per instance address, all sharing one explicit
    /// failover policy (timeouts, reconnect budget, backoff).
    pub fn connect_with(addrs: &[SocketAddr], cfg: FailoverConfig) -> Result<Self> {
        let clients = addrs
            .iter()
            .map(|&a| Client::connect_with(a, cfg))
            .collect::<Result<Vec<_>>>()?;
        let plan = (0..clients.len()).map(|_| ShardPlan::default()).collect();
        Ok(Self { clients, put_batch: BATCH_PAIRS, plan })
    }

    /// Wire bytes re-sent during failover replay, summed over all
    /// shards — observability only, never charged to the ledger (which
    /// is what keeps a faulted run's footprint byte-identical to a
    /// fault-free one).
    pub fn wasted_sent(&self) -> u64 {
        self.clients.iter().map(|c| c.wasted_sent).sum()
    }

    /// Install a shard-indexed address lookup consulted on every
    /// reconnect. In cluster mode a killed shard *process* is respawned
    /// on a fresh ephemeral port; the driver publishes the new address
    /// through the shard map, and `lookup(i)` resolves shard `i`'s
    /// current address so failover replay lands on the respawned
    /// process instead of retrying the dead port.
    pub fn set_rediscover(
        &mut self,
        lookup: std::sync::Arc<dyn Fn(usize) -> Option<SocketAddr> + Send + Sync>,
    ) {
        for (i, client) in self.clients.iter_mut().enumerate() {
            let lookup = lookup.clone();
            client.set_rediscover(std::sync::Arc::new(move || lookup(i)));
        }
    }

    fn shard_of(&self, seq: u64) -> usize {
        (seq % self.clients.len() as u64) as usize
    }

    /// Group packed indexes per shard, remembering original positions.
    fn plan_fetch(&self, indexes: &[i64]) -> Vec<(Vec<usize>, Vec<(Vec<u8>, usize)>)> {
        let n = self.clients.len();
        let mut per_shard: Vec<(Vec<usize>, Vec<(Vec<u8>, usize)>)> =
            vec![(Vec::new(), Vec::new()); n];
        for (pos, &idx) in indexes.iter().enumerate() {
            let (seq, off) = unpack_index(idx);
            let shard = self.shard_of(seq);
            per_shard[shard].0.push(pos);
            per_shard[shard].1.push((key_of(seq), off));
        }
        per_shard
    }

    fn scatter(
        indexes: &[i64],
        per_shard: &[(Vec<usize>, Vec<(Vec<u8>, usize)>)],
        results: Vec<Result<Vec<Option<Vec<u8>>>>>,
    ) -> Result<Vec<Vec<u8>>> {
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); indexes.len()];
        for ((positions, _), replies) in per_shard.iter().zip(results) {
            let replies = replies?;
            // a short reply (server bug / protocol desync) must be an
            // error, not silently-empty trailing texts — the arena path
            // guards this per chunk in mgetsuffix_pipelined_into
            if replies.len() != positions.len() {
                return Err(KvError::Server(format!(
                    "shard replied {} texts for {} requests",
                    replies.len(),
                    positions.len()
                )));
            }
            for (pos, r) in positions.iter().zip(replies) {
                out[*pos] = r.ok_or_else(|| {
                    KvError::Server(format!("missing read for index {}", indexes[*pos]))
                })?;
            }
        }
        Ok(out)
    }

    fn traffic_delta(&self, before: Traffic) -> Traffic {
        let after = self.traffic();
        Traffic {
            sent: after.sent - before.sent,
            received: after.received - before.received,
        }
    }

    /// Baseline fetch: byte-identical commands to [`SuffixStore::fetch_suffixes`]
    /// (same per-shard grouping, same `BATCH_PAIRS` chunking) but issued
    /// one blocking round trip at a time, one shard after another — no
    /// pipelining, no cross-shard concurrency. Exists so benchmarks and
    /// equivalence tests can isolate what the overlapped path buys.
    pub fn fetch_suffixes_sequential(
        &mut self,
        indexes: &[i64],
    ) -> Result<(Vec<Vec<u8>>, Traffic)> {
        let before = self.traffic();
        let per_shard = self.plan_fetch(indexes);
        let mut results: Vec<Result<Vec<Option<Vec<u8>>>>> = Vec::new();
        for (client, (_, reqs)) in self.clients.iter_mut().zip(per_shard.iter()) {
            let mut replies = Vec::with_capacity(reqs.len());
            let mut res = Ok(());
            for chunk in reqs.chunks(BATCH_PAIRS) {
                match client.mgetsuffix(chunk) {
                    Ok(mut vs) => replies.append(&mut vs),
                    Err(e) => {
                        res = Err(e);
                        break;
                    }
                }
            }
            results.push(res.map(|()| replies));
        }
        let out = Self::scatter(indexes, &per_shard, results)?;
        Ok((out, self.traffic_delta(before)))
    }
}

impl SuffixStore for ShardedClient {
    fn put_reads(&mut self, reads: &[Read]) -> Result<Traffic> {
        let before = self.traffic();
        let n = self.clients.len();
        let mut per_shard: Vec<Vec<(Vec<u8>, Vec<u8>)>> = vec![Vec::new(); n];
        for r in reads {
            per_shard[(r.seq % n as u64) as usize].push((key_of(r.seq), r.codes.clone()));
        }
        // one windowed MSET pipeline per shard, all shards concurrently
        let batch = self.put_batch;
        let results = for_each_shard(
            &mut self.clients,
            &mut per_shard,
            |pairs: &Vec<(Vec<u8>, Vec<u8>)>| pairs.is_empty(),
            |client, pairs| client.mset_pipelined(pairs, batch),
        );
        for r in results {
            r?;
        }
        Ok(self.traffic_delta(before))
    }

    fn fetch_suffixes(&mut self, indexes: &[i64]) -> Result<(Vec<Vec<u8>>, Traffic)> {
        let before = self.traffic();
        let mut per_shard = self.plan_fetch(indexes);
        // one windowed MGETSUFFIX pipeline per shard, all shards
        // concurrently: fetch latency hides behind the slowest shard
        // instead of the sum of all shards
        let results = for_each_shard(
            &mut self.clients,
            &mut per_shard,
            |(_, reqs): &(Vec<usize>, Vec<(Vec<u8>, usize)>)| reqs.is_empty(),
            |client, (_, reqs)| client.mgetsuffix_pipelined(reqs, BATCH_PAIRS),
        );
        let out = Self::scatter(indexes, &per_shard, results)?;
        Ok((out, self.traffic_delta(before)))
    }

    fn fetch_suffixes_into(&mut self, indexes: &[i64], out: &mut SuffixBatch) -> Result<Traffic> {
        let before = self.traffic();
        // plan into the reused scratch: same mod-N grouping and request
        // order as plan_fetch, but (seq, off) pairs instead of key Vecs —
        // the keys are formatted into a stack buffer at send time
        let n = self.clients.len();
        for p in &mut self.plan {
            p.positions.clear();
            p.reqs.clear();
            p.arena.clear();
        }
        for (pos, &idx) in indexes.iter().enumerate() {
            let (seq, off) = unpack_index(idx);
            let shard = (seq % n as u64) as usize;
            self.plan[shard].positions.push(pos);
            self.plan[shard].reqs.push((seq, off));
        }
        // one pipeline per shard, each streaming replies into its own
        // reused arena, all shards concurrently
        let results = for_each_shard(
            &mut self.clients,
            &mut self.plan,
            |p: &ShardPlan| p.reqs.is_empty(),
            |client, p| client.mgetsuffix_pipelined_into(&p.reqs, BATCH_PAIRS, &mut p.arena),
        );
        // interleave back to request order: per-shard arenas are appended
        // wholesale (one bulk copy per SHARD, not per suffix) and the
        // per-suffix work is a spans permutation
        let base_entry = out.len();
        out.reserve_slots(indexes.len());
        for (p, res) in self.plan.iter().zip(results) {
            res?;
            let base = out.append_arena(&p.arena);
            for (j, &pos) in p.positions.iter().enumerate() {
                match p.arena.entry_span(j) {
                    Some((start, len)) => out.set_slot(base_entry + pos, base + start, len),
                    None => {
                        return Err(KvError::Server(format!(
                            "missing read for index {}",
                            indexes[pos]
                        )))
                    }
                }
            }
        }
        Ok(self.traffic_delta(before))
    }

    fn traffic(&self) -> Traffic {
        let mut t = Traffic::default();
        for c in &self.clients {
            t.sent += c.bytes_sent;
            t.received += c.bytes_received;
        }
        t
    }

    fn used_memory(&mut self) -> u64 {
        self.clients
            .iter_mut()
            .map(|c| c.used_memory().unwrap_or(0) as u64)
            .sum()
    }

    fn n_shards(&self) -> usize {
        self.clients.len()
    }

    fn set_put_batch(&mut self, pairs: usize) {
        self.put_batch = pairs.max(1);
    }
}

// ---------------------------------------------------------------------
// In-process sharded store (no sockets; same stores, modeled wire bytes)
// ---------------------------------------------------------------------

/// In-process store: the same [`Store`] per shard and the same batched
/// command surface, but dispatched directly. Wire bytes are *modeled*
/// with the RESP encoding rules, so the footprint ledger sees the same
/// numbers the TCP path would produce. Used by the cluster simulator and
/// by unit tests that don't want sockets.
pub struct InProcStore {
    shards: Vec<Store>,
    traffic: Traffic,
    put_batch: usize,
    /// Reusable per-shard fetch plan (request positions) — zero
    /// steady-state allocations, same as the TCP client's scratch.
    plan: Vec<Vec<usize>>,
}

impl InProcStore {
    /// A fresh store with `n_shards` independent instances.
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards > 0);
        Self {
            shards: (0..n_shards).map(|_| Store::new()).collect(),
            traffic: Traffic::default(),
            put_batch: BATCH_PAIRS,
            plan: (0..n_shards).map(|_| Vec::new()).collect(),
        }
    }

    /// Direct access to one shard's store.
    pub fn shard(&self, i: usize) -> &Store {
        &self.shards[i]
    }

    fn wire_len_of_cmd(args_len: &[usize]) -> u64 {
        // *N\r\n + per-arg $len\r\n...\r\n
        let mut total = 1 + dec_len(args_len.len() as u64) as u64 + 2;
        for &l in args_len {
            total += resp::bulk_wire_len(l);
        }
        total
    }
}

impl SuffixStore for InProcStore {
    fn put_reads(&mut self, reads: &[Read]) -> Result<Traffic> {
        let before = self.traffic;
        let n = self.shards.len();
        let mut per_shard: Vec<Vec<&Read>> = vec![Vec::new(); n];
        for r in reads {
            per_shard[(r.seq % n as u64) as usize].push(r);
        }
        for (shard, rs) in per_shard.into_iter().enumerate() {
            for chunk in rs.chunks(self.put_batch) {
                let mut arg_lens = vec![4usize]; // "MSET"
                for r in chunk {
                    let k = key_of(r.seq);
                    arg_lens.push(k.len());
                    arg_lens.push(r.codes.len());
                    self.shards[shard].set_exact(k, r.codes.clone());
                }
                self.traffic.sent += Self::wire_len_of_cmd(&arg_lens);
                self.traffic.received += Value::ok().wire_len();
            }
        }
        Ok(Traffic {
            sent: self.traffic.sent - before.sent,
            received: self.traffic.received - before.received,
        })
    }

    fn fetch_suffixes(&mut self, indexes: &[i64]) -> Result<(Vec<Vec<u8>>, Traffic)> {
        // Deliberately NOT a wrapper over fetch_suffixes_into: this is
        // the preserved pre-arena path (per-request key Vecs, per-suffix
        // output Vecs), kept independent so the equivalence tests compare
        // two real implementations and the fetch bench's baseline pays
        // exactly what the old code paid.
        let before = self.traffic;
        let n = self.shards.len();
        let mut per_shard: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (pos, &idx) in indexes.iter().enumerate() {
            let (seq, _) = unpack_index(idx);
            per_shard[(seq % n as u64) as usize].push(pos);
        }
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); indexes.len()];
        for (shard, positions) in per_shard.into_iter().enumerate() {
            for chunk in positions.chunks(BATCH_PAIRS) {
                let mut arg_lens = vec![10usize]; // "MGETSUFFIX"
                let mut reply_lens: Vec<usize> = Vec::with_capacity(chunk.len());
                for &pos in chunk {
                    let (seq, off) = unpack_index(indexes[pos]);
                    let k = key_of(seq);
                    arg_lens.push(k.len());
                    arg_lens.push(dec_len(off as u64));
                    let suffix = self.shards[shard].get_suffix(&k, off).ok_or_else(|| {
                        KvError::Server(format!("missing read for index {}", indexes[pos]))
                    })?;
                    reply_lens.push(suffix.len());
                    out[pos] = suffix.to_vec();
                }
                self.traffic.sent += Self::wire_len_of_cmd(&arg_lens);
                // reply: *N + bulk per suffix
                let mut rl = 1 + dec_len(chunk.len() as u64) as u64 + 2;
                for l in reply_lens {
                    rl += resp::bulk_wire_len(l);
                }
                self.traffic.received += rl;
            }
        }
        let delta = Traffic {
            sent: self.traffic.sent - before.sent,
            received: self.traffic.received - before.received,
        };
        Ok((out, delta))
    }

    fn fetch_suffixes_into(&mut self, indexes: &[i64], out: &mut SuffixBatch) -> Result<Traffic> {
        let before = self.traffic;
        let n = self.shards.len();
        // plan into the reused scratch (taken out of self so the loop can
        // borrow shards immutably while charging traffic mutably); an
        // earlier error return leaves the scratch empty, so re-grow it
        let mut plan = std::mem::take(&mut self.plan);
        plan.resize_with(n, Vec::new);
        for p in &mut plan {
            p.clear();
        }
        for (pos, &idx) in indexes.iter().enumerate() {
            let (seq, _) = unpack_index(idx);
            plan[(seq % n as u64) as usize].push(pos);
        }
        let base_entry = out.len();
        out.reserve_slots(indexes.len());
        let mut keybuf = [0u8; 20];
        for (shard, positions) in plan.iter().enumerate() {
            for chunk in positions.chunks(BATCH_PAIRS) {
                // wire lengths modeled arithmetically (identical numbers
                // to the old materializing loop, no Vec per argument)
                let n_args = 1 + chunk.len() * 2;
                let mut sent = 1 + dec_len(n_args as u64) as u64 + 2;
                sent += resp::bulk_wire_len(10); // "MGETSUFFIX"
                let mut received = 1 + dec_len(chunk.len() as u64) as u64 + 2;
                for &pos in chunk {
                    let (seq, off) = unpack_index(indexes[pos]);
                    let key = fmt_dec(seq, &mut keybuf);
                    sent += resp::bulk_wire_len(key.len());
                    sent += resp::bulk_wire_len(dec_len(off as u64));
                    let suffix = self.shards[shard].get_suffix(key, off).ok_or_else(|| {
                        KvError::Server(format!("missing read for index {}", indexes[pos]))
                    })?;
                    received += resp::bulk_wire_len(suffix.len());
                    out.fill_slot(base_entry + pos, suffix);
                }
                self.traffic.sent += sent;
                self.traffic.received += received;
            }
        }
        self.plan = plan;
        Ok(Traffic {
            sent: self.traffic.sent - before.sent,
            received: self.traffic.received - before.received,
        })
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn used_memory(&mut self) -> u64 {
        self.shards.iter().map(|s| s.used_memory()).sum()
    }

    fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn set_put_batch(&mut self, pairs: usize) {
        self.put_batch = pairs.max(1);
    }
}

/// Cloneable handle sharing one [`InProcStore`] across tasks/threads —
/// the simulator-mode counterpart of per-task TCP clients.
#[derive(Clone)]
pub struct SharedStore(pub std::sync::Arc<std::sync::Mutex<InProcStore>>);

impl SharedStore {
    /// A fresh shared store with `n_shards` instances.
    pub fn new(n_shards: usize) -> Self {
        Self(std::sync::Arc::new(std::sync::Mutex::new(InProcStore::new(n_shards))))
    }
}

impl SuffixStore for SharedStore {
    fn put_reads(&mut self, reads: &[Read]) -> Result<Traffic> {
        self.0.lock().unwrap().put_reads(reads)
    }

    fn fetch_suffixes(&mut self, indexes: &[i64]) -> Result<(Vec<Vec<u8>>, Traffic)> {
        self.0.lock().unwrap().fetch_suffixes(indexes)
    }

    fn fetch_suffixes_into(&mut self, indexes: &[i64], out: &mut SuffixBatch) -> Result<Traffic> {
        self.0.lock().unwrap().fetch_suffixes_into(indexes, out)
    }

    fn traffic(&self) -> Traffic {
        self.0.lock().unwrap().traffic()
    }

    fn used_memory(&mut self) -> u64 {
        self.0.lock().unwrap().used_memory()
    }

    fn n_shards(&self) -> usize {
        self.0.lock().unwrap().n_shards()
    }

    fn set_put_batch(&mut self, pairs: usize) {
        self.0.lock().unwrap().set_put_batch(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::encode::pack_index;

    fn corpus() -> Vec<Read> {
        vec![
            Read::from_ascii(0, b"ACGT"),
            Read::from_ascii(1, b"TTAA"),
            Read::from_ascii(2, b"GATTACA"),
            Read::from_ascii(7, b"CCC"),
        ]
    }

    #[test]
    fn inproc_put_fetch_roundtrip() {
        let mut st = InProcStore::new(3);
        st.put_reads(&corpus()).unwrap();
        let reqs = vec![
            pack_index(2, 0),
            pack_index(2, 3),
            pack_index(0, 4), // '$' suffix -> empty
            pack_index(7, 1),
        ];
        let (got, delta) = st.fetch_suffixes(&reqs).unwrap();
        assert!(delta.sent > 0 && delta.received > 0);
        assert_eq!(got[0], Read::from_ascii(0, b"GATTACA").codes);
        assert_eq!(got[1], Read::from_ascii(0, b"TACA").codes);
        assert_eq!(got[2], Vec::<u8>::new());
        assert_eq!(got[3], Read::from_ascii(0, b"CC").codes);
        assert!(st.traffic().sent > 0 && st.traffic().received > 0);
    }

    #[test]
    fn inproc_missing_read_errors() {
        let mut st = InProcStore::new(2);
        st.put_reads(&corpus()).unwrap();
        assert!(st.fetch_suffixes(&[pack_index(99, 0)]).is_err());
        let mut batch = SuffixBatch::new();
        assert!(st.fetch_suffixes_into(&[pack_index(99, 0)], &mut batch).is_err());
        // the store must recover after an error (scratch re-grown)
        batch.clear();
        st.fetch_suffixes_into(&[pack_index(2, 3)], &mut batch).unwrap();
        assert_eq!(batch.slice(0), &Read::from_ascii(0, b"TACA").codes[..]);
    }

    #[test]
    fn inproc_arena_fetch_matches_vec_fetch() {
        let mut st = InProcStore::new(3);
        st.put_reads(&corpus()).unwrap();
        let reqs = vec![
            pack_index(2, 0),
            pack_index(7, 1),
            pack_index(0, 4),
            pack_index(2, 3),
            pack_index(1, 0),
        ];
        let (vecs, t_vec) = st.fetch_suffixes(&reqs).unwrap();
        let mut batch = SuffixBatch::new();
        // two rounds through the same reused batch: reuse must not leak
        // previous entries into the next fetch
        for _ in 0..2 {
            batch.clear();
            let t_arena = st.fetch_suffixes_into(&reqs, &mut batch).unwrap();
            assert_eq!(t_arena, t_vec, "identical modeled wire traffic");
            assert_eq!(batch.len(), vecs.len());
            for (i, v) in vecs.iter().enumerate() {
                assert_eq!(batch.slice(i), &v[..], "entry {i}");
            }
        }
    }

    #[test]
    fn sharding_distributes_by_mod() {
        let mut st = InProcStore::new(2);
        st.put_reads(&corpus()).unwrap();
        // seqs 0,2 -> shard 0; seqs 1,7 -> shard 1
        assert_eq!(st.shard(0).len(), 2);
        assert_eq!(st.shard(1).len(), 2);
    }

    #[test]
    fn smaller_put_batch_costs_more_wire_overhead() {
        // §IV-B aggregation: fewer pairs per MSET -> more command framing
        let reads: Vec<Read> = (0..64u64).map(|i| Read::new(i, vec![1u8; 50])).collect();
        let mut big = InProcStore::new(2);
        big.set_put_batch(64);
        let t_big = big.put_reads(&reads).unwrap();
        let mut small = InProcStore::new(2);
        small.set_put_batch(4);
        let t_small = small.put_reads(&reads).unwrap();
        assert!(
            t_small.total() > t_big.total(),
            "small batches must cost more: {} vs {}",
            t_small.total(),
            t_big.total()
        );
    }

    #[test]
    fn suffix_fetch_halves_traffic_vs_whole_reads() {
        // §IV-B: fetching suffixes (avg len/2) instead of whole reads
        // should roughly halve received bytes for uniform offsets.
        let reads: Vec<Read> = (0..200u64)
            .map(|i| Read::new(i, vec![1u8; 100]))
            .collect();
        let mut st = InProcStore::new(4);
        st.put_reads(&reads).unwrap();
        let t0 = st.traffic();
        // fetch every suffix of every read
        let mut reqs = Vec::new();
        for r in &reads {
            for o in 0..=r.len() {
                reqs.push(pack_index(r.seq, o));
            }
        }
        let (_, fetch_delta) = st.fetch_suffixes(&reqs).unwrap();
        let received = st.traffic().received - t0.received;
        assert_eq!(received, fetch_delta.received);
        let whole_reads_lower_bound: u64 = reqs.len() as u64 * 100;
        let suffix_payload: u64 = reads.iter().map(|_| (100 * 101 / 2) as u64).sum();
        assert!(received > suffix_payload); // payload + protocol overhead
        assert!(received < whole_reads_lower_bound); // far below whole-read fetches
    }
}

//! RESP (REdis Serialization Protocol) subset — the wire format of the
//! in-memory data store. Enough of RESP2 for the pipeline: simple strings,
//! errors, integers, bulk strings (incl. null), arrays.

use std::io::{self, BufRead, Write};

/// One RESP value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Value {
    /// `+...` simple string.
    Simple(String),
    /// `-...` error string.
    Error(String),
    /// `:...` integer.
    Int(i64),
    /// `$n` bulk string (binary safe).
    Bulk(Vec<u8>),
    /// `$-1` null bulk.
    Null,
    /// `*n` array of values.
    Array(Vec<Value>),
}

impl Value {
    /// The `+OK` simple string.
    pub fn ok() -> Self {
        Value::Simple("OK".into())
    }

    /// A bulk string from any byte source.
    pub fn bulk(b: impl Into<Vec<u8>>) -> Self {
        Value::Bulk(b.into())
    }

    /// Wire size in bytes (used for network-traffic accounting without
    /// re-serializing).
    pub fn wire_len(&self) -> u64 {
        match self {
            Value::Simple(s) => 1 + s.len() as u64 + 2,
            Value::Error(s) => 1 + s.len() as u64 + 2,
            Value::Int(i) => 1 + i.to_string().len() as u64 + 2,
            Value::Bulk(b) => 1 + b.len().to_string().len() as u64 + 2 + b.len() as u64 + 2,
            Value::Null => 5, // $-1\r\n
            Value::Array(vs) => {
                1 + vs.len().to_string().len() as u64
                    + 2
                    + vs.iter().map(Value::wire_len).sum::<u64>()
            }
        }
    }
}

/// Encode a value to a writer.
pub fn write_value(w: &mut impl Write, v: &Value) -> io::Result<()> {
    match v {
        Value::Simple(s) => write!(w, "+{s}\r\n"),
        Value::Error(s) => write!(w, "-{s}\r\n"),
        Value::Int(i) => write!(w, ":{i}\r\n"),
        Value::Bulk(b) => {
            write!(w, "${}\r\n", b.len())?;
            w.write_all(b)?;
            w.write_all(b"\r\n")
        }
        Value::Null => w.write_all(b"$-1\r\n"),
        Value::Array(vs) => {
            write!(w, "*{}\r\n", vs.len())?;
            for v in vs {
                write_value(w, v)?;
            }
            Ok(())
        }
    }
}

/// Encode a command (array of bulk strings), the client->server direction.
/// Writes directly — no Value materialization on the request hot path.
pub fn write_command(w: &mut impl Write, args: &[&[u8]]) -> io::Result<()> {
    write!(w, "*{}\r\n", args.len())?;
    for a in args {
        write!(w, "${}\r\n", a.len())?;
        w.write_all(a)?;
        w.write_all(b"\r\n")?;
    }
    Ok(())
}

/// Wire length of a command without materializing it.
pub fn command_wire_len(args: &[&[u8]]) -> u64 {
    let mut total = 1 + args.len().to_string().len() as u64 + 2;
    for a in args {
        total += 1 + a.len().to_string().len() as u64 + 2 + a.len() as u64 + 2;
    }
    total
}

/// Read one CRLF-terminated line into `scratch` (reused across calls —
/// RESP decoding is per-suffix on the reduce hot path, and a String
/// allocation per protocol line measurably hurts; §Perf iteration 5b).
fn read_line_into<'a>(r: &mut impl BufRead, scratch: &'a mut Vec<u8>) -> io::Result<&'a [u8]> {
    scratch.clear();
    r.read_until(b'\n', scratch)?;
    if scratch.len() < 2 || &scratch[scratch.len() - 2..] != b"\r\n" {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "RESP line without CRLF",
        ));
    }
    let n = scratch.len() - 2;
    Ok(&scratch[..n])
}

fn parse_int(bytes: &[u8]) -> io::Result<i64> {
    std::str::from_utf8(bytes)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad RESP integer"))
}

/// Decode one value from a reader.
pub fn read_value(r: &mut impl BufRead) -> io::Result<Value> {
    let mut scratch = Vec::with_capacity(64);
    read_value_buf(r, &mut scratch)
}

fn read_value_buf(r: &mut impl BufRead, scratch: &mut Vec<u8>) -> io::Result<Value> {
    let bad = |what: &str| io::Error::new(io::ErrorKind::InvalidData, what.to_string());
    let line = read_line_into(r, scratch)?;
    if line.is_empty() {
        return Err(bad("empty RESP line"));
    }
    let (tag, rest) = (line[0], &line[1..]);
    match tag {
        b'+' => Ok(Value::Simple(String::from_utf8_lossy(rest).into_owned())),
        b'-' => Ok(Value::Error(String::from_utf8_lossy(rest).into_owned())),
        b':' => parse_int(rest).map(Value::Int),
        b'$' => {
            let n = parse_int(rest)?;
            if n < 0 {
                return Ok(Value::Null);
            }
            let mut buf = vec![0u8; n as usize + 2];
            r.read_exact(&mut buf)?;
            if &buf[n as usize..] != b"\r\n" {
                return Err(bad("bulk without CRLF"));
            }
            buf.truncate(n as usize);
            Ok(Value::Bulk(buf))
        }
        b'*' => {
            let n = parse_int(rest)?;
            if n < 0 {
                return Ok(Value::Null);
            }
            let mut vs = Vec::with_capacity(n as usize);
            for _ in 0..n {
                vs.push(read_value_buf(r, scratch)?);
            }
            Ok(Value::Array(vs))
        }
        _ => Err(bad("unknown RESP tag")),
    }
}

/// Decode a command into argv (must be an array of bulks).
pub fn read_command(r: &mut impl BufRead) -> io::Result<Option<Vec<Vec<u8>>>> {
    match read_value(r) {
        Ok(Value::Array(vs)) => {
            let mut args = Vec::with_capacity(vs.len());
            for v in vs {
                match v {
                    Value::Bulk(b) => args.push(b),
                    _ => {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "command args must be bulk strings",
                        ))
                    }
                }
            }
            Ok(Some(args))
        }
        Ok(_) => Err(io::Error::new(io::ErrorKind::InvalidData, "command must be array")),
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => Ok(None),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn roundtrip(v: &Value) -> Value {
        let mut buf = Vec::new();
        write_value(&mut buf, v).unwrap();
        assert_eq!(buf.len() as u64, v.wire_len(), "wire_len of {v:?}");
        read_value(&mut BufReader::new(&buf[..])).unwrap()
    }

    #[test]
    fn roundtrips() {
        for v in [
            Value::ok(),
            Value::Error("ERR nope".into()),
            Value::Int(-42),
            Value::bulk(b"hello".to_vec()),
            Value::bulk(b"".to_vec()),
            Value::Null,
            Value::Array(vec![Value::Int(1), Value::bulk(b"x".to_vec()), Value::Null]),
            Value::Array(vec![]),
        ] {
            assert_eq!(roundtrip(&v), v);
        }
    }

    #[test]
    fn command_roundtrip() {
        let mut buf = Vec::new();
        write_command(&mut buf, &[b"SET", b"k1", b"v1"]).unwrap();
        let got = read_command(&mut BufReader::new(&buf[..])).unwrap().unwrap();
        assert_eq!(got, vec![b"SET".to_vec(), b"k1".to_vec(), b"v1".to_vec()]);
    }

    #[test]
    fn eof_is_none() {
        let empty: &[u8] = b"";
        assert!(read_command(&mut BufReader::new(empty)).unwrap().is_none());
    }

    #[test]
    fn binary_safe_bulk() {
        let v = Value::bulk(vec![0u8, 1, 2, 3, 255, b'\r', b'\n']);
        assert_eq!(roundtrip(&v), v);
    }
}

//! RESP (REdis Serialization Protocol) subset — the wire format of the
//! in-memory data store. Enough of RESP2 for the pipeline: simple strings,
//! errors, integers, bulk strings (incl. null), arrays.
//!
//! Two reply readers exist: [`read_value`] materializes a [`Value`]
//! (one `Vec` per bulk — the original path, kept as the equivalence
//! baseline), and [`read_bulk_array_into`] streams an `MGETSUFFIX`-style
//! array of bulks straight into a caller-provided
//! [`SuffixBatch`](crate::kvstore::batch::SuffixBatch) arena — the
//! zero-copy fetch hot path, which never allocates per suffix.

use std::io::{self, BufRead, Write};

use crate::kvstore::batch::SuffixBatch;
use crate::util::bytes::dec_len;

/// One RESP value.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Value {
    /// `+...` simple string.
    Simple(String),
    /// `-...` error string.
    Error(String),
    /// `:...` integer.
    Int(i64),
    /// `$n` bulk string (binary safe).
    Bulk(Vec<u8>),
    /// `$-1` null bulk.
    Null,
    /// `*n` array of values.
    Array(Vec<Value>),
}

impl Value {
    /// The `+OK` simple string.
    pub fn ok() -> Self {
        Value::Simple("OK".into())
    }

    /// A bulk string from any byte source.
    pub fn bulk(b: impl Into<Vec<u8>>) -> Self {
        Value::Bulk(b.into())
    }

    /// Wire size in bytes (used for network-traffic accounting without
    /// re-serializing).
    pub fn wire_len(&self) -> u64 {
        match self {
            Value::Simple(s) => 1 + s.len() as u64 + 2,
            Value::Error(s) => 1 + s.len() as u64 + 2,
            Value::Int(i) => 1 + i.to_string().len() as u64 + 2,
            Value::Bulk(b) => bulk_wire_len(b.len()),
            Value::Null => 5, // $-1\r\n
            Value::Array(vs) => {
                1 + dec_len(vs.len() as u64) as u64
                    + 2
                    + vs.iter().map(Value::wire_len).sum::<u64>()
            }
        }
    }
}

/// Encode a value to a writer.
pub fn write_value(w: &mut impl Write, v: &Value) -> io::Result<()> {
    match v {
        Value::Simple(s) => write!(w, "+{s}\r\n"),
        Value::Error(s) => write!(w, "-{s}\r\n"),
        Value::Int(i) => write!(w, ":{i}\r\n"),
        Value::Bulk(b) => {
            write!(w, "${}\r\n", b.len())?;
            w.write_all(b)?;
            w.write_all(b"\r\n")
        }
        Value::Null => w.write_all(b"$-1\r\n"),
        Value::Array(vs) => {
            write!(w, "*{}\r\n", vs.len())?;
            for v in vs {
                write_value(w, v)?;
            }
            Ok(())
        }
    }
}

/// Encode a command (array of bulk strings), the client->server direction.
/// Writes directly — no Value materialization on the request hot path.
pub fn write_command(w: &mut impl Write, args: &[&[u8]]) -> io::Result<()> {
    write!(w, "*{}\r\n", args.len())?;
    for a in args {
        write!(w, "${}\r\n", a.len())?;
        w.write_all(a)?;
        w.write_all(b"\r\n")?;
    }
    Ok(())
}

/// Wire length of one bulk string of `len` payload bytes:
/// `$<len>\r\n<payload>\r\n`.
pub fn bulk_wire_len(len: usize) -> u64 {
    1 + dec_len(len as u64) as u64 + 2 + len as u64 + 2
}

/// Wire length of a command without materializing it (and, since the
/// zero-copy refactor, without a digit-count `to_string` per argument).
pub fn command_wire_len(args: &[&[u8]]) -> u64 {
    let mut total = 1 + dec_len(args.len() as u64) as u64 + 2;
    for a in args {
        total += bulk_wire_len(a.len());
    }
    total
}

/// Read one CRLF-terminated line into `scratch` (reused across calls —
/// RESP decoding is per-suffix on the reduce hot path, and a String
/// allocation per protocol line measurably hurts; §Perf iteration 5b).
fn read_line_into<'a>(r: &mut impl BufRead, scratch: &'a mut Vec<u8>) -> io::Result<&'a [u8]> {
    scratch.clear();
    r.read_until(b'\n', scratch)?;
    if scratch.len() < 2 || &scratch[scratch.len() - 2..] != b"\r\n" {
        return Err(io::Error::new(
            io::ErrorKind::UnexpectedEof,
            "RESP line without CRLF",
        ));
    }
    let n = scratch.len() - 2;
    Ok(&scratch[..n])
}

fn parse_int(bytes: &[u8]) -> io::Result<i64> {
    std::str::from_utf8(bytes)
        .ok()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad RESP integer"))
}

/// Decode one value from a reader.
pub fn read_value(r: &mut impl BufRead) -> io::Result<Value> {
    let mut scratch = Vec::with_capacity(64);
    read_value_buf(r, &mut scratch)
}

/// Decode the remainder of a *scalar* value whose tag line (`tag` +
/// `rest`) has already been consumed: simple string, error, integer,
/// bulk (incl. null). Shared by [`read_value`] and
/// [`read_bulk_array_into`]'s cold path, so the two readers can never
/// disagree on scalar decoding. Arrays are each caller's business.
fn read_scalar(r: &mut impl BufRead, tag: u8, rest: &[u8]) -> io::Result<Value> {
    let bad = |what: &str| io::Error::new(io::ErrorKind::InvalidData, what.to_string());
    match tag {
        b'+' => Ok(Value::Simple(String::from_utf8_lossy(rest).into_owned())),
        b'-' => Ok(Value::Error(String::from_utf8_lossy(rest).into_owned())),
        b':' => parse_int(rest).map(Value::Int),
        b'$' => {
            let n = parse_int(rest)?;
            if n < 0 {
                return Ok(Value::Null);
            }
            let mut buf = vec![0u8; n as usize + 2];
            r.read_exact(&mut buf)?;
            if &buf[n as usize..] != b"\r\n" {
                return Err(bad("bulk without CRLF"));
            }
            buf.truncate(n as usize);
            Ok(Value::Bulk(buf))
        }
        _ => Err(bad("unknown RESP tag")),
    }
}

fn read_value_buf(r: &mut impl BufRead, scratch: &mut Vec<u8>) -> io::Result<Value> {
    let bad = |what: &str| io::Error::new(io::ErrorKind::InvalidData, what.to_string());
    let line = read_line_into(r, scratch)?;
    if line.is_empty() {
        return Err(bad("empty RESP line"));
    }
    let (tag, rest) = (line[0], &line[1..]);
    if tag == b'*' {
        let n = parse_int(rest)?;
        if n < 0 {
            return Ok(Value::Null);
        }
        let mut vs = Vec::with_capacity(n as usize);
        for _ in 0..n {
            vs.push(read_value_buf(r, scratch)?);
        }
        return Ok(Value::Array(vs));
    }
    read_scalar(r, tag, rest)
}

/// Shape of one reply consumed by [`read_bulk_array_into`].
#[derive(Debug)]
pub enum ArrayReply {
    /// A `*n` array of bulk/null elements: `n` entries were appended to
    /// the batch in wire order (null bulks as missing entries), and the
    /// whole reply measured `wire_len` bytes. No per-element `Vec`s.
    Appended {
        /// Elements appended to the batch.
        n: usize,
        /// Total wire bytes of the reply (accounting input).
        wire_len: u64,
    },
    /// Any other reply shape (server errors included), materialized as a
    /// [`Value`] — the cold path; a healthy fetch never takes it.
    Other(Value),
}

/// Append exactly `n` payload bytes from `r` to `batch`'s arena by
/// copying straight out of the reader's internal buffer — append-only,
/// no pre-zeroing pass over the payload (the fetch path is meant to be
/// memory-bandwidth-bound; a `resize` + `read_exact` would write every
/// byte twice).
fn append_exact(r: &mut impl BufRead, batch: &mut SuffixBatch, mut n: usize) -> io::Result<()> {
    while n > 0 {
        let avail = r.fill_buf()?;
        if avail.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "bulk payload truncated",
            ));
        }
        let take = avail.len().min(n);
        batch.append_raw(&avail[..take]);
        r.consume(take);
        n -= take;
    }
    Ok(())
}

/// Decode one reply, streaming an array-of-bulks payload straight into
/// `batch`'s arena: per element, the payload bytes move reader buffer →
/// arena in one append, with no intermediate `Vec` — the client side of
/// the zero-copy `MGETSUFFIX` path. `scratch` is the reused line buffer
/// (the caller owns it so a pipelined connection allocates nothing per
/// reply).
///
/// An array element that is not a bulk/null is a protocol violation and
/// surfaces as `InvalidData` (partial entries may remain in `batch`;
/// callers discard the batch on error).
pub fn read_bulk_array_into(
    r: &mut impl BufRead,
    scratch: &mut Vec<u8>,
    batch: &mut SuffixBatch,
) -> io::Result<ArrayReply> {
    let bad = |what: &str| io::Error::new(io::ErrorKind::InvalidData, what.to_string());
    let line = read_line_into(r, scratch)?;
    if line.is_empty() {
        return Err(bad("empty RESP line"));
    }
    let (tag, rest) = (line[0], &line[1..]);
    match tag {
        b'*' => {
            let n = parse_int(rest)?;
            if n < 0 {
                return Ok(ArrayReply::Other(Value::Null));
            }
            let n = n as usize;
            let mut wire = 1 + dec_len(n as u64) as u64 + 2;
            for _ in 0..n {
                let line = read_line_into(r, scratch)?;
                if line.first() != Some(&b'$') {
                    return Err(bad("bulk-array element is not a bulk string"));
                }
                let len = parse_int(&line[1..])?;
                if len < 0 {
                    batch.push_missing();
                    wire += 5; // $-1\r\n
                    continue;
                }
                let len = len as usize;
                append_exact(r, batch, len)?;
                let mut crlf = [0u8; 2];
                r.read_exact(&mut crlf)?;
                if &crlf != b"\r\n" {
                    return Err(bad("bulk without CRLF"));
                }
                batch.seal_entry(len);
                wire += bulk_wire_len(len);
            }
            Ok(ArrayReply::Appended { n, wire_len: wire })
        }
        // cold path: scalar replies (errors included) decode through the
        // same helper `read_value` uses
        _ => read_scalar(r, tag, rest).map(ArrayReply::Other),
    }
}

/// Decode a command into argv (must be an array of bulks).
pub fn read_command(r: &mut impl BufRead) -> io::Result<Option<Vec<Vec<u8>>>> {
    match read_value(r) {
        Ok(Value::Array(vs)) => {
            let mut args = Vec::with_capacity(vs.len());
            for v in vs {
                match v {
                    Value::Bulk(b) => args.push(b),
                    _ => {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "command args must be bulk strings",
                        ))
                    }
                }
            }
            Ok(Some(args))
        }
        Ok(_) => Err(io::Error::new(io::ErrorKind::InvalidData, "command must be array")),
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => Ok(None),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn roundtrip(v: &Value) -> Value {
        let mut buf = Vec::new();
        write_value(&mut buf, v).unwrap();
        assert_eq!(buf.len() as u64, v.wire_len(), "wire_len of {v:?}");
        read_value(&mut BufReader::new(&buf[..])).unwrap()
    }

    #[test]
    fn roundtrips() {
        for v in [
            Value::ok(),
            Value::Error("ERR nope".into()),
            Value::Int(-42),
            Value::bulk(b"hello".to_vec()),
            Value::bulk(b"".to_vec()),
            Value::Null,
            Value::Array(vec![Value::Int(1), Value::bulk(b"x".to_vec()), Value::Null]),
            Value::Array(vec![]),
        ] {
            assert_eq!(roundtrip(&v), v);
        }
    }

    #[test]
    fn command_roundtrip() {
        let mut buf = Vec::new();
        write_command(&mut buf, &[b"SET", b"k1", b"v1"]).unwrap();
        let got = read_command(&mut BufReader::new(&buf[..])).unwrap().unwrap();
        assert_eq!(got, vec![b"SET".to_vec(), b"k1".to_vec(), b"v1".to_vec()]);
    }

    #[test]
    fn eof_is_none() {
        let empty: &[u8] = b"";
        assert!(read_command(&mut BufReader::new(empty)).unwrap().is_none());
    }

    #[test]
    fn binary_safe_bulk() {
        let v = Value::bulk(vec![0u8, 1, 2, 3, 255, b'\r', b'\n']);
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn streamed_bulk_array_matches_materialized() {
        use crate::kvstore::batch::SuffixBatch;
        // array with bulks (binary-safe, empty) and a null
        let v = Value::Array(vec![
            Value::bulk(b"ACGT".to_vec()),
            Value::Null,
            Value::bulk(b"".to_vec()),
            Value::bulk(vec![0u8, b'\r', b'\n', 255]),
        ]);
        let mut wire = Vec::new();
        write_value(&mut wire, &v).unwrap();
        let mut scratch = Vec::new();
        let mut batch = SuffixBatch::new();
        let got = read_bulk_array_into(&mut BufReader::new(&wire[..]), &mut scratch, &mut batch)
            .unwrap();
        match got {
            ArrayReply::Appended { n, wire_len } => {
                assert_eq!(n, 4);
                assert_eq!(wire_len, v.wire_len());
                assert_eq!(wire_len, wire.len() as u64);
            }
            other => panic!("expected Appended, got {other:?}"),
        }
        assert_eq!(batch.get(0), Some(&b"ACGT"[..]));
        assert_eq!(batch.get(1), None);
        assert_eq!(batch.get(2), Some(&b""[..]));
        assert_eq!(batch.get(3), Some(&[0u8, b'\r', b'\n', 255][..]));
    }

    #[test]
    fn streamed_reader_surfaces_other_replies() {
        use crate::kvstore::batch::SuffixBatch;
        let mut scratch = Vec::new();
        let mut batch = SuffixBatch::new();
        for v in [Value::Error("ERR nope".into()), Value::ok(), Value::Int(3), Value::Null] {
            let mut wire = Vec::new();
            write_value(&mut wire, &v).unwrap();
            let got =
                read_bulk_array_into(&mut BufReader::new(&wire[..]), &mut scratch, &mut batch)
                    .unwrap();
            match got {
                ArrayReply::Other(o) => assert_eq!(o, v),
                other => panic!("expected Other({v:?}), got {other:?}"),
            }
        }
        assert!(batch.is_empty());
        // a non-bulk array element is a protocol violation
        let v = Value::Array(vec![Value::Int(1)]);
        let mut wire = Vec::new();
        write_value(&mut wire, &v).unwrap();
        let err = read_bulk_array_into(&mut BufReader::new(&wire[..]), &mut scratch, &mut batch)
            .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }
}

//! Background suffix prefetcher — the reducer's double buffer.
//!
//! The paper's own breakdown (§IV-D) puts ~60% of reducer wall time in
//! `MGETSUFFIX` fetches. The reducer alternates CPU-bound phases (numeric
//! sort, tie-break compare) with network-bound ones (suffix fetch), so a
//! single dedicated fetch thread per reducer is enough to hide one behind
//! the other: while sorting group *i* is tie-break sorted and emitted,
//! group *i+1*'s texts are already streaming in.
//!
//! Texts travel as flat [`SuffixBatch`] arenas, and the arenas are
//! *recycled*: the caller hands an arena in with each
//! [`SuffixPrefetcher::request`] (typically the one it just finished
//! consuming) and gets it back, filled, from
//! [`SuffixPrefetcher::wait`]. With one batch in flight and one being
//! consumed, two arenas rotate forever — steady state does zero arena
//! allocations (`tests/alloc_count.rs`).
//!
//! Requests are answered strictly in FIFO order and are byte-identical to
//! the blocking path — the prefetcher only moves *when* the fetch runs,
//! never *what* is fetched — so the footprint ledger sees exactly the
//! same wire totals with or without it (property-tested in
//! `tests/fetch_equivalence.rs`).
//!
//! Fault tolerance needs no code here: shard failover lives inside
//! [`Client`](crate::kvstore::client::Client), below the [`SuffixStore`]
//! handle this worker drives, so an in-flight prefetch rides out a shard
//! kill by transparent reconnect-and-replay on the fetch thread. The
//! worker never charges the footprint ledger (its traffic is returned to
//! — and charged by — the reducer task thread), which is what lets the
//! engine attribute every charge of a retried attempt to that attempt's
//! ledger via thread-local redirection (`tests/fault_tolerance.rs`).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::kvstore::batch::SuffixBatch;
use crate::kvstore::client::{KvError, Result};
use crate::kvstore::shard::{SuffixStore, Traffic};

/// One in-flight-capable fetch worker wrapping a [`SuffixStore`] handle.
pub struct SuffixPrefetcher {
    tx: Option<Sender<(Vec<i64>, SuffixBatch)>>,
    rx: Receiver<Result<(SuffixBatch, Traffic)>>,
    worker: Option<JoinHandle<()>>,
    in_flight: usize,
}

impl SuffixPrefetcher {
    /// Move `store` onto a dedicated fetch thread and return the handle
    /// used to overlap fetches with caller-side work.
    pub fn spawn(mut store: Box<dyn SuffixStore>) -> SuffixPrefetcher {
        let (tx, req_rx) = channel::<(Vec<i64>, SuffixBatch)>();
        let (res_tx, rx) = channel();
        let worker = std::thread::Builder::new()
            .name("samr-prefetch".into())
            .spawn(move || {
                while let Ok((indexes, mut batch)) = req_rx.recv() {
                    batch.clear();
                    let res = store.fetch_suffixes_into(&indexes, &mut batch).map(|t| (batch, t));
                    if res_tx.send(res).is_err() {
                        break; // owner dropped
                    }
                }
            })
            .expect("spawn prefetch thread");
        SuffixPrefetcher { tx: Some(tx), rx, worker: Some(worker), in_flight: 0 }
    }

    /// Queue a fetch into `batch` (cleared on the worker before filling —
    /// pass a recycled arena to keep steady state allocation-free);
    /// returns immediately. Results arrive in request order via
    /// [`SuffixPrefetcher::wait`].
    pub fn request(&mut self, indexes: Vec<i64>, batch: SuffixBatch) {
        self.tx
            .as_ref()
            .expect("prefetcher running")
            .send((indexes, batch))
            .expect("prefetch thread alive");
        self.in_flight += 1;
    }

    /// Number of requests queued but not yet collected.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Block until the oldest outstanding request completes and return
    /// its filled arena (entries in request order) plus the wire traffic
    /// it caused.
    pub fn wait(&mut self) -> Result<(SuffixBatch, Traffic)> {
        assert!(self.in_flight > 0, "no prefetch in flight");
        self.in_flight -= 1;
        self.rx
            .recv()
            .map_err(|_| KvError::Server("prefetch thread died".into()))?
    }
}

impl Drop for SuffixPrefetcher {
    fn drop(&mut self) {
        self.tx.take(); // closing the channel stops the worker loop
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::shard::SharedStore;
    use crate::suffix::encode::pack_index;
    use crate::suffix::reads::Read;

    #[test]
    fn overlapped_requests_come_back_in_order() {
        let mut store = SharedStore::new(2);
        let reads: Vec<Read> =
            (0..10u64).map(|i| Read::new(i, vec![(i % 4 + 1) as u8; 8])).collect();
        store.put_reads(&reads).unwrap();
        let mut pf = SuffixPrefetcher::spawn(Box::new(store.clone()));
        pf.request(vec![pack_index(3, 0)], SuffixBatch::new());
        pf.request(vec![pack_index(7, 2)], SuffixBatch::new());
        assert_eq!(pf.in_flight(), 2);
        let (first, t1) = pf.wait().unwrap();
        let (second, t2) = pf.wait().unwrap();
        assert_eq!(first.slice(0), &[4u8; 8][..]);
        assert_eq!(second.slice(0), &[4u8; 6][..]);
        assert!(t1.total() > 0 && t2.total() > 0);
        assert_eq!(pf.in_flight(), 0);
    }

    #[test]
    fn recycled_arenas_are_cleared_before_reuse() {
        let mut store = SharedStore::new(1);
        let reads: Vec<Read> = (0..4u64).map(|i| Read::new(i, vec![2u8; 6])).collect();
        store.put_reads(&reads).unwrap();
        let mut pf = SuffixPrefetcher::spawn(Box::new(store.clone()));
        pf.request(vec![pack_index(0, 0), pack_index(1, 3)], SuffixBatch::new());
        let (batch, _) = pf.wait().unwrap();
        assert_eq!(batch.len(), 2);
        // hand the same arena back, still full: the worker must clear it
        pf.request(vec![pack_index(2, 1)], batch);
        let (batch, _) = pf.wait().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.slice(0), &[2u8; 5][..]);
    }

    #[test]
    fn fetch_errors_surface_on_wait() {
        let store = SharedStore::new(1);
        let mut pf = SuffixPrefetcher::spawn(Box::new(store));
        pf.request(vec![pack_index(42, 0)], SuffixBatch::new()); // nothing stored
        assert!(pf.wait().is_err());
    }
}

//! Blocking client for one KV instance, with pipelining — the Jedis role.
//! Tracks wire bytes in both directions for the network-footprint ledger.
//!
//! All bulk traffic (mapper `MSET` puts and reducer `MGETSUFFIX` fetches)
//! goes through one windowed pipeline: up to [`PIPELINE_WINDOW`] batched
//! commands stay in flight per connection, so request serialization,
//! server-side dispatch, and reply deserialization overlap instead of
//! alternating in lockstep round trips.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};

use crate::kvstore::batch::SuffixBatch;
use crate::kvstore::resp::{self, Value};
use crate::util::bytes::{dec_len, fmt_dec};

/// Connection to one KV instance (reader/writer halves of one socket).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Reused RESP line scratch for the streaming (arena) reply path.
    scratch: Vec<u8>,
    /// Request wire bytes written so far (footprint ledger input).
    pub bytes_sent: u64,
    /// Reply wire bytes read so far (footprint ledger input).
    pub bytes_received: u64,
}

/// Client-side KV error: transport, server-reported, or protocol.
#[derive(Debug)]
pub enum KvError {
    /// Socket/transport failure.
    Io(std::io::Error),
    /// The server replied with a RESP error.
    Server(String),
    /// The server replied with a value of the wrong shape.
    Unexpected(Value),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::Io(e) => write!(f, "io: {e}"),
            KvError::Server(e) => write!(f, "server error: {e}"),
            KvError::Unexpected(v) => write!(f, "unexpected reply: {v:?}"),
        }
    }
}

impl std::error::Error for KvError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            KvError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for KvError {
    fn from(e: std::io::Error) -> Self {
        KvError::Io(e)
    }
}

/// A KV failure as an `io::Error` — how a clean fetch/put error travels
/// through the reducer and the job engine (which speak `io::Result`)
/// without becoming a panic. Transport errors keep their `ErrorKind`.
impl From<KvError> for std::io::Error {
    fn from(e: KvError) -> Self {
        match e {
            KvError::Io(e) => e,
            other => std::io::Error::other(format!("kv store: {other}")),
        }
    }
}

/// Client-side KV result.
pub type Result<T> = std::result::Result<T, KvError>;

/// Batched commands kept in flight per connection. Keep a few chunks
/// moving so request serialization overlaps server work, but bounded —
/// sending everything before reading anything fills both directions'
/// socket buffers and the connection degenerates into lockstep stalls
/// under concurrency (measured 18× collapse; §Perf iteration 5).
pub const PIPELINE_WINDOW: usize = 3;

impl Client {
    /// Connect to a KV instance (TCP_NODELAY, split buffered halves).
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let conn = TcpStream::connect(addr)?;
        conn.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(conn.try_clone()?),
            writer: BufWriter::new(conn),
            scratch: Vec::with_capacity(32),
            bytes_sent: 0,
            bytes_received: 0,
        })
    }

    fn send(&mut self, args: &[&[u8]]) -> Result<()> {
        self.bytes_sent += resp::command_wire_len(args);
        resp::write_command(&mut self.writer, args)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Value> {
        let v = resp::read_value(&mut self.reader)?;
        self.bytes_received += v.wire_len();
        if let Value::Error(e) = v {
            return Err(KvError::Server(e));
        }
        Ok(v)
    }

    fn call(&mut self, args: &[&[u8]]) -> Result<Value> {
        self.send(args)?;
        self.writer.flush()?;
        self.recv()
    }

    /// Issue `n_cmds` commands through the bounded pipeline window and
    /// collect their replies in order. `send_cmd(client, i)` serializes
    /// the i-th command; steady state tops the window up by one command
    /// per reply received, so the link stays busy in both directions.
    fn pipelined(
        &mut self,
        n_cmds: usize,
        mut send_cmd: impl FnMut(&mut Client, usize) -> Result<()>,
    ) -> Result<Vec<Value>> {
        let mut out = Vec::with_capacity(n_cmds);
        let mut sent = 0;
        while out.len() < n_cmds {
            while sent < n_cmds && sent - out.len() < PIPELINE_WINDOW {
                send_cmd(self, sent)?;
                sent += 1;
            }
            self.writer.flush()?;
            out.push(self.recv()?);
        }
        Ok(out)
    }

    /// Health check.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&[b"PING"])? {
            Value::Bulk(b) if b == b"PONG" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Store one key/value pair.
    pub fn set(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        match self.call(&[b"SET", key, value])? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Fetch one value.
    pub fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        match self.call(&[b"GET", key])? {
            Value::Bulk(b) => Ok(Some(b)),
            Value::Null => Ok(None),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Batched SET of many records in one round trip (the paper's
    /// "mappers aggregate the reads assigned to the same Redis instance
    /// and put them at one time").
    pub fn mset(&mut self, pairs: &[(Vec<u8>, Vec<u8>)]) -> Result<()> {
        if pairs.is_empty() {
            return Ok(());
        }
        let mut args: Vec<&[u8]> = Vec::with_capacity(1 + pairs.len() * 2);
        args.push(b"MSET");
        for (k, v) in pairs {
            args.push(k);
            args.push(v);
        }
        match self.call(&args)? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Pipelined batched SET: `pairs` split into `chunk_pairs`-sized
    /// `MSET` commands pushed through the window, so the mapper-side put
    /// of a whole split costs ~one round trip per window drain instead of
    /// one per batch (§IV-B aggregation, overlapped).
    pub fn mset_pipelined(
        &mut self,
        pairs: &[(Vec<u8>, Vec<u8>)],
        chunk_pairs: usize,
    ) -> Result<()> {
        if pairs.is_empty() {
            return Ok(());
        }
        let chunks: Vec<&[(Vec<u8>, Vec<u8>)]> = pairs.chunks(chunk_pairs.max(1)).collect();
        let replies = self.pipelined(chunks.len(), |c, i| {
            let chunk = chunks[i];
            let mut args: Vec<&[u8]> = Vec::with_capacity(1 + chunk.len() * 2);
            args.push(b"MSET");
            for (k, v) in chunk {
                args.push(k);
                args.push(v);
            }
            c.send(&args)
        })?;
        for v in replies {
            match v {
                Value::Simple(s) if s == "OK" => {}
                v => return Err(KvError::Unexpected(v)),
            }
        }
        Ok(())
    }

    /// Windowed pipelined `MGETSUFFIX`: `reqs` split into
    /// `chunk_pairs`-sized commands pushed through the window. Replies
    /// are collected in request order.
    pub fn mgetsuffix_pipelined(
        &mut self,
        reqs: &[(Vec<u8>, usize)],
        chunk_pairs: usize,
    ) -> Result<Vec<Option<Vec<u8>>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let chunks: Vec<&[(Vec<u8>, usize)]> = reqs.chunks(chunk_pairs.max(1)).collect();
        let replies = self.pipelined(chunks.len(), |c, i| {
            let chunk = chunks[i];
            let offs: Vec<Vec<u8>> =
                chunk.iter().map(|(_, o)| o.to_string().into_bytes()).collect();
            let mut args: Vec<&[u8]> = Vec::with_capacity(1 + chunk.len() * 2);
            args.push(b"MGETSUFFIX");
            for ((k, _), o) in chunk.iter().zip(&offs) {
                args.push(k);
                args.push(o);
            }
            c.send(&args)
        })?;
        let mut out = Vec::with_capacity(reqs.len());
        for reply in replies {
            match reply {
                Value::Array(vs) => {
                    for v in vs {
                        match v {
                            Value::Bulk(b) => out.push(Some(b)),
                            Value::Null => out.push(None),
                            v => return Err(KvError::Unexpected(v)),
                        }
                    }
                }
                v => return Err(KvError::Unexpected(v)),
            }
        }
        Ok(out)
    }

    /// Serialize one `MGETSUFFIX` command for `chunk` without building
    /// an argv: keys and offsets are formatted through a stack buffer
    /// (no `to_string().into_bytes()` per request) and written straight
    /// to the connection's buffered writer. Bytes and accounting are
    /// identical to `write_command` over the equivalent argv.
    fn send_mgetsuffix(&mut self, chunk: &[(u64, usize)]) -> Result<()> {
        let n_args = 1 + chunk.len() * 2;
        let mut wire = 1 + dec_len(n_args as u64) as u64 + 2;
        wire += resp::bulk_wire_len(b"MGETSUFFIX".len());
        write!(self.writer, "*{n_args}\r\n$10\r\nMGETSUFFIX\r\n")?;
        let mut buf = [0u8; 20];
        for &(seq, off) in chunk {
            let key = fmt_dec(seq, &mut buf);
            wire += resp::bulk_wire_len(key.len());
            write!(self.writer, "${}\r\n", key.len())?;
            self.writer.write_all(key)?;
            self.writer.write_all(b"\r\n")?;
            let off = fmt_dec(off as u64, &mut buf);
            wire += resp::bulk_wire_len(off.len());
            write!(self.writer, "${}\r\n", off.len())?;
            self.writer.write_all(off)?;
            self.writer.write_all(b"\r\n")?;
        }
        self.bytes_sent += wire;
        Ok(())
    }

    /// Windowed pipelined `MGETSUFFIX` appending the replies into `out`'s
    /// arena — the zero-copy fetch path. One entry per request in request
    /// order (missing keys as missing entries); requests are (sequence
    /// number, offset) pairs formatted on the fly. Wire bytes in both
    /// directions are identical to [`Client::mgetsuffix_pipelined`] over
    /// the same requests — only the reply's destination changes: socket
    /// buffer → arena in one append per suffix, zero per-suffix `Vec`s.
    ///
    /// On error, entries already appended to `out` are unspecified;
    /// callers discard the batch.
    pub fn mgetsuffix_pipelined_into(
        &mut self,
        reqs: &[(u64, usize)],
        chunk_pairs: usize,
        out: &mut SuffixBatch,
    ) -> Result<()> {
        if reqs.is_empty() {
            return Ok(());
        }
        let chunk = chunk_pairs.max(1);
        let n_chunks = reqs.len().div_ceil(chunk);
        let bounds = |i: usize| (i * chunk, ((i + 1) * chunk).min(reqs.len()));
        let mut sent = 0;
        let mut done = 0;
        while done < n_chunks {
            while sent < n_chunks && sent - done < PIPELINE_WINDOW {
                let (lo, hi) = bounds(sent);
                self.send_mgetsuffix(&reqs[lo..hi])?;
                sent += 1;
            }
            self.writer.flush()?;
            let (lo, hi) = bounds(done);
            match resp::read_bulk_array_into(&mut self.reader, &mut self.scratch, out)? {
                resp::ArrayReply::Appended { n, wire_len } => {
                    self.bytes_received += wire_len;
                    if n != hi - lo {
                        return Err(KvError::Server(format!(
                            "MGETSUFFIX replied {n} elements for {} requests",
                            hi - lo
                        )));
                    }
                }
                resp::ArrayReply::Other(v) => {
                    self.bytes_received += v.wire_len();
                    if let Value::Error(e) = v {
                        return Err(KvError::Server(e));
                    }
                    return Err(KvError::Unexpected(v));
                }
            }
            done += 1;
        }
        Ok(())
    }

    /// The paper's `mgetsuffix`: fetch value[offset..] for many
    /// (key, offset) pairs in one round trip.
    pub fn mgetsuffix(&mut self, reqs: &[(Vec<u8>, usize)]) -> Result<Vec<Option<Vec<u8>>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let offs: Vec<Vec<u8>> = reqs.iter().map(|(_, o)| o.to_string().into_bytes()).collect();
        let mut args: Vec<&[u8]> = Vec::with_capacity(1 + reqs.len() * 2);
        args.push(b"MGETSUFFIX");
        for ((k, _), o) in reqs.iter().zip(&offs) {
            args.push(k);
            args.push(o);
        }
        match self.call(&args)? {
            Value::Array(vs) => vs
                .into_iter()
                .map(|v| match v {
                    Value::Bulk(b) => Ok(Some(b)),
                    Value::Null => Ok(None),
                    v => Err(KvError::Unexpected(v)),
                })
                .collect(),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Number of keys stored.
    pub fn dbsize(&mut self) -> Result<i64> {
        match self.call(&[b"DBSIZE"])? {
            Value::Int(i) => Ok(i),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Memory used by the instance (payload + metadata model).
    pub fn used_memory(&mut self) -> Result<i64> {
        match self.call(&[b"MEMORY"])? {
            Value::Int(i) => Ok(i),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Drop every key.
    pub fn flushdb(&mut self) -> Result<()> {
        match self.call(&[b"FLUSHDB"])? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }
}

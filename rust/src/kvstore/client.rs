//! Blocking client for one KV instance, with pipelining — the Jedis role.
//! Tracks wire bytes in both directions for the network-footprint ledger.
//!
//! All bulk traffic (mapper `MSET` puts and reducer `MGETSUFFIX` fetches)
//! goes through one windowed pipeline: up to [`PIPELINE_WINDOW`] batched
//! commands stay in flight per connection, so request serialization,
//! server-side dispatch, and reply deserialization overlap instead of
//! alternating in lockstep round trips.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use crate::kvstore::batch::SuffixBatch;
use crate::kvstore::resp::{self, Value};
use crate::util::bytes::{dec_len, fmt_dec};

/// Connect/read/write deadlines and retry/backoff policy for one shard
/// connection. A dead or wedged shard surfaces as a bounded sequence of
/// reconnect attempts with deterministic capped exponential backoff —
/// never an unbounded hang on a socket read.
#[derive(Clone, Copy, Debug)]
pub struct FailoverConfig {
    /// Deadline per TCP connect attempt.
    pub connect_timeout: Duration,
    /// Connect attempts per (re)connection, backoff-spaced.
    pub connect_attempts: u32,
    /// First backoff delay; doubles per retry (deterministic, no jitter
    /// — reproducibility outranks thundering-herd avoidance here).
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Socket read deadline (`None` = block forever).
    pub read_timeout: Option<Duration>,
    /// Socket write deadline (`None` = block forever).
    pub write_timeout: Option<Duration>,
    /// Reconnect-and-replay cycles per operation before giving up.
    pub failover_attempts: u32,
}

impl Default for FailoverConfig {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(2),
            connect_attempts: 5,
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(200),
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(30)),
            failover_attempts: 8,
        }
    }
}

impl FailoverConfig {
    /// Delay before retry `n` (0-based): `backoff_base * 2^n`, capped.
    pub fn backoff_delay(&self, n: u32) -> Duration {
        self.backoff_base
            .checked_mul(1u32 << n.min(16))
            .map(|d| d.min(self.backoff_cap))
            .unwrap_or(self.backoff_cap)
    }
}

/// Connection to one KV instance (reader/writer halves of one socket).
///
/// The connection self-heals: a transport error inside an idempotent
/// operation (every command here is idempotent — `MSET` re-puts
/// identical pairs, `MGETSUFFIX` re-reads) triggers reconnect-and-replay
/// of the in-flight pipeline window, bounded by
/// [`FailoverConfig::failover_attempts`]. Accounting stays *logical*:
/// `bytes_sent`/`bytes_received` count each command and each complete
/// reply exactly once, so ledger totals are byte-identical to a
/// fault-free run; re-sent wire bytes are tallied in `wasted_sent`.
/// (There is no `wasted_received`: replay never re-requests a chunk
/// whose reply was completely received, and partial replies are never
/// charged.)
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Shard address, kept for reconnects and error context.
    addr: SocketAddr,
    /// Failover policy for this connection.
    cfg: FailoverConfig,
    /// Optional address rediscovery, consulted before every reconnect:
    /// a shard *process* that died and was respawned listens on a fresh
    /// ephemeral port, so retrying the old address forever would never
    /// find it. `None` (the default) reconnects to `addr` as before.
    rediscover: Option<Rediscover>,
    /// True while re-sending already-charged commands after a reconnect;
    /// routes wire charges to `wasted_sent` instead of `bytes_sent`.
    replaying: bool,
    /// Reused RESP line scratch for the streaming (arena) reply path.
    scratch: Vec<u8>,
    /// Logical request wire bytes (footprint ledger input): each command
    /// charged exactly once, on first send.
    pub bytes_sent: u64,
    /// Logical reply wire bytes (footprint ledger input): each reply
    /// charged exactly once, on complete receipt.
    pub bytes_received: u64,
    /// Wire bytes re-sent during failover replay — observability only,
    /// never part of the ledger.
    pub wasted_sent: u64,
}

/// Client-side KV error: transport, server-reported, or protocol.
#[derive(Debug)]
pub enum KvError {
    /// Socket/transport failure.
    Io(std::io::Error),
    /// The server replied with a RESP error.
    Server(String),
    /// The server replied with a value of the wrong shape.
    Unexpected(Value),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::Io(e) => write!(f, "io: {e}"),
            KvError::Server(e) => write!(f, "server error: {e}"),
            KvError::Unexpected(v) => write!(f, "unexpected reply: {v:?}"),
        }
    }
}

impl std::error::Error for KvError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            KvError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for KvError {
    fn from(e: std::io::Error) -> Self {
        KvError::Io(e)
    }
}

/// A KV failure as an `io::Error` — how a clean fetch/put error travels
/// through the reducer and the job engine (which speak `io::Result`)
/// without becoming a panic. Transport errors keep their `ErrorKind`.
impl From<KvError> for std::io::Error {
    fn from(e: KvError) -> Self {
        match e {
            KvError::Io(e) => e,
            other => std::io::Error::other(format!("kv store: {other}")),
        }
    }
}

/// Client-side KV result.
pub type Result<T> = std::result::Result<T, KvError>;

/// Attach shard address + command context to an error, so a multi-shard
/// failure names its source ("shard 127.0.0.1:6399: MGETSUFFIX: ...").
/// Transport errors keep their `ErrorKind`; server errors keep their
/// text; protocol-shape errors already carry the offending value.
fn ctx(addr: SocketAddr, cmd: &str, e: KvError) -> KvError {
    match e {
        KvError::Io(io) => KvError::Io(std::io::Error::new(
            io.kind(),
            format!("shard {addr}: {cmd}: {io}"),
        )),
        KvError::Server(s) => KvError::Server(format!("shard {addr}: {cmd}: {s}")),
        other => other,
    }
}

/// Address-rediscovery callback: returns the shard's current address
/// (e.g. read from the driver-maintained shard map file), or `None` to
/// keep the last known one.
pub type Rediscover = Arc<dyn Fn() -> Option<SocketAddr> + Send + Sync>;

/// Batched commands kept in flight per connection. Keep a few chunks
/// moving so request serialization overlaps server work, but bounded —
/// sending everything before reading anything fills both directions'
/// socket buffers and the connection degenerates into lockstep stalls
/// under concurrency (measured 18× collapse; §Perf iteration 5).
pub const PIPELINE_WINDOW: usize = 3;

impl Client {
    /// Connect to a KV instance with default failover policy
    /// (TCP_NODELAY, split buffered halves).
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        Self::connect_with(addr, FailoverConfig::default())
    }

    /// Connect with an explicit failover policy.
    pub fn connect_with(addr: SocketAddr, cfg: FailoverConfig) -> Result<Client> {
        let conn = Self::open_socket(addr, &cfg)?;
        Ok(Client {
            reader: BufReader::new(conn.try_clone().map_err(|e| ctx(addr, "connect", e.into()))?),
            writer: BufWriter::new(conn),
            addr,
            cfg,
            rediscover: None,
            replaying: false,
            scratch: Vec::with_capacity(32),
            bytes_sent: 0,
            bytes_received: 0,
            wasted_sent: 0,
        })
    }

    /// Open a socket to `addr` under `cfg`: per-attempt connect
    /// deadline, bounded attempts, capped exponential backoff between
    /// them, and read/write deadlines installed on success.
    fn open_socket(addr: SocketAddr, cfg: &FailoverConfig) -> Result<TcpStream> {
        let attempts = cfg.connect_attempts.max(1);
        let mut last: Option<std::io::Error> = None;
        for n in 0..attempts {
            if n > 0 {
                std::thread::sleep(cfg.backoff_delay(n - 1));
            }
            match TcpStream::connect_timeout(&addr, cfg.connect_timeout) {
                Ok(conn) => {
                    conn.set_nodelay(true).ok();
                    conn.set_read_timeout(cfg.read_timeout).ok();
                    conn.set_write_timeout(cfg.write_timeout).ok();
                    return Ok(conn);
                }
                Err(e) => last = Some(e),
            }
        }
        let e = last.expect("at least one connect attempt");
        Err(ctx(
            addr,
            "connect",
            KvError::Io(std::io::Error::new(
                e.kind(),
                format!("{e} (after {attempts} attempts)"),
            )),
        ))
    }

    /// Install an address-rediscovery callback (see [`Rediscover`]).
    pub fn set_rediscover(&mut self, lookup: Rediscover) {
        self.rediscover = Some(lookup);
    }

    /// Tear down the broken halves and dial the shard again — at the
    /// rediscovered address if a callback is installed and knows a newer
    /// one. The old `BufWriter`'s unflushed bytes are deliberately
    /// discarded — the caller replays its in-flight window on the fresh
    /// connection.
    fn reconnect(&mut self) -> Result<()> {
        if let Some(addr) = self.rediscover.as_ref().and_then(|f| f()) {
            self.addr = addr;
        }
        let conn = Self::open_socket(self.addr, &self.cfg)?;
        self.reader = BufReader::new(conn.try_clone().map_err(|e| ctx(self.addr, "connect", e.into()))?);
        self.writer = BufWriter::new(conn);
        Ok(())
    }

    /// Charge `wire` request bytes: logical on first send, wasted on a
    /// failover replay — so `bytes_sent` stays byte-identical to a
    /// fault-free run.
    fn charge_sent(&mut self, wire: u64) {
        if self.replaying {
            self.wasted_sent += wire;
        } else {
            self.bytes_sent += wire;
        }
    }

    pub(crate) fn send(&mut self, args: &[&[u8]]) -> Result<()> {
        self.charge_sent(resp::command_wire_len(args));
        resp::write_command(&mut self.writer, args)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Value> {
        let v = resp::read_value(&mut self.reader)?;
        self.bytes_received += v.wire_len();
        if let Value::Error(e) = v {
            return Err(KvError::Server(e));
        }
        Ok(v)
    }

    /// One command, one reply — with bounded reconnect-and-retry on
    /// transport failure (every command this client speaks is
    /// idempotent). The command is charged to `bytes_sent` once;
    /// retried sends charge `wasted_sent`.
    pub(crate) fn call(&mut self, args: &[&[u8]]) -> Result<Value> {
        let cmd = String::from_utf8_lossy(args[0]).into_owned();
        self.replaying = false;
        let mut tries = 0u32;
        loop {
            let r = (|| {
                self.send(args)?;
                self.replaying = false;
                self.writer.flush()?;
                self.recv()
            })();
            match r {
                Err(KvError::Io(_)) if tries + 1 < self.cfg.failover_attempts.max(1) => {
                    tries += 1;
                    std::thread::sleep(self.cfg.backoff_delay(tries - 1));
                    self.reconnect()?;
                    // replay: the command was already charged as logical
                    self.replaying = true;
                }
                other => {
                    self.replaying = false;
                    return other.map_err(|e| ctx(self.addr, &cmd, e));
                }
            }
        }
    }

    /// Issue `n_cmds` commands through the bounded pipeline window and
    /// collect their replies in order. `send_cmd(client, i)` serializes
    /// the i-th command; steady state tops the window up by one command
    /// per reply received, so the link stays busy in both directions.
    ///
    /// On a transport failure the client reconnects (bounded, backed
    /// off) and replays the idempotent in-flight window — commands sent
    /// but not yet answered — instead of wedging the caller. Completed
    /// replies are never re-requested; replayed sends charge
    /// `wasted_sent`, so logical accounting matches a fault-free run.
    pub(crate) fn pipelined(
        &mut self,
        n_cmds: usize,
        mut send_cmd: impl FnMut(&mut Client, usize) -> Result<()>,
    ) -> Result<Vec<Value>> {
        let mut out = Vec::with_capacity(n_cmds);
        self.replaying = false;
        let mut sent = 0usize;
        // commands charged as logical so far: anything below this mark
        // is a replay when sent again
        let mut charged = 0usize;
        let mut tries = 0u32;
        while out.len() < n_cmds {
            let step = 'step: {
                while sent < n_cmds && sent - out.len() < PIPELINE_WINDOW {
                    self.replaying = sent < charged;
                    charged = charged.max(sent + 1);
                    let r = send_cmd(self, sent);
                    self.replaying = false;
                    if let Err(e) = r {
                        break 'step Err(e);
                    }
                    sent += 1;
                }
                if let Err(e) = self.writer.flush() {
                    break 'step Err(e.into());
                }
                self.recv()
            };
            match step {
                Ok(v) => out.push(v),
                Err(KvError::Io(_)) if tries + 1 < self.cfg.failover_attempts.max(1) => {
                    tries += 1;
                    std::thread::sleep(self.cfg.backoff_delay(tries - 1));
                    self.reconnect()?;
                    sent = out.len(); // replay the unanswered window
                }
                Err(e) => return Err(e),
            }
        }
        Ok(out)
    }

    /// Health check.
    pub fn ping(&mut self) -> Result<()> {
        match self.call(&[b"PING"])? {
            Value::Bulk(b) if b == b"PONG" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Store one key/value pair.
    pub fn set(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        match self.call(&[b"SET", key, value])? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Fetch one value.
    pub fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        match self.call(&[b"GET", key])? {
            Value::Bulk(b) => Ok(Some(b)),
            Value::Null => Ok(None),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Batched SET of many records in one round trip (the paper's
    /// "mappers aggregate the reads assigned to the same Redis instance
    /// and put them at one time").
    pub fn mset(&mut self, pairs: &[(Vec<u8>, Vec<u8>)]) -> Result<()> {
        if pairs.is_empty() {
            return Ok(());
        }
        let mut args: Vec<&[u8]> = Vec::with_capacity(1 + pairs.len() * 2);
        args.push(b"MSET");
        for (k, v) in pairs {
            args.push(k);
            args.push(v);
        }
        match self.call(&args)? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Pipelined batched SET: `pairs` split into `chunk_pairs`-sized
    /// `MSET` commands pushed through the window, so the mapper-side put
    /// of a whole split costs ~one round trip per window drain instead of
    /// one per batch (§IV-B aggregation, overlapped).
    pub fn mset_pipelined(
        &mut self,
        pairs: &[(Vec<u8>, Vec<u8>)],
        chunk_pairs: usize,
    ) -> Result<()> {
        if pairs.is_empty() {
            return Ok(());
        }
        let chunks: Vec<&[(Vec<u8>, Vec<u8>)]> = pairs.chunks(chunk_pairs.max(1)).collect();
        let replies = self
            .pipelined(chunks.len(), |c, i| {
                let chunk = chunks[i];
                let mut args: Vec<&[u8]> = Vec::with_capacity(1 + chunk.len() * 2);
                args.push(b"MSET");
                for (k, v) in chunk {
                    args.push(k);
                    args.push(v);
                }
                c.send(&args)
            })
            .map_err(|e| ctx(self.addr, "MSET", e))?;
        for v in replies {
            match v {
                Value::Simple(s) if s == "OK" => {}
                v => return Err(KvError::Unexpected(v)),
            }
        }
        Ok(())
    }

    /// Windowed pipelined `MGETSUFFIX`: `reqs` split into
    /// `chunk_pairs`-sized commands pushed through the window. Replies
    /// are collected in request order.
    pub fn mgetsuffix_pipelined(
        &mut self,
        reqs: &[(Vec<u8>, usize)],
        chunk_pairs: usize,
    ) -> Result<Vec<Option<Vec<u8>>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let chunks: Vec<&[(Vec<u8>, usize)]> = reqs.chunks(chunk_pairs.max(1)).collect();
        let replies = self
            .pipelined(chunks.len(), |c, i| {
                let chunk = chunks[i];
                let offs: Vec<Vec<u8>> =
                    chunk.iter().map(|(_, o)| o.to_string().into_bytes()).collect();
                let mut args: Vec<&[u8]> = Vec::with_capacity(1 + chunk.len() * 2);
                args.push(b"MGETSUFFIX");
                for ((k, _), o) in chunk.iter().zip(&offs) {
                    args.push(k);
                    args.push(o);
                }
                c.send(&args)
            })
            .map_err(|e| ctx(self.addr, "MGETSUFFIX", e))?;
        let mut out = Vec::with_capacity(reqs.len());
        for reply in replies {
            match reply {
                Value::Array(vs) => {
                    for v in vs {
                        match v {
                            Value::Bulk(b) => out.push(Some(b)),
                            Value::Null => out.push(None),
                            v => return Err(KvError::Unexpected(v)),
                        }
                    }
                }
                v => return Err(KvError::Unexpected(v)),
            }
        }
        Ok(out)
    }

    /// Serialize one `MGETSUFFIX` command for `chunk` without building
    /// an argv: keys and offsets are formatted through a stack buffer
    /// (no `to_string().into_bytes()` per request) and written straight
    /// to the connection's buffered writer. Bytes and accounting are
    /// identical to `write_command` over the equivalent argv.
    fn send_mgetsuffix(&mut self, chunk: &[(u64, usize)]) -> Result<()> {
        let n_args = 1 + chunk.len() * 2;
        let mut wire = 1 + dec_len(n_args as u64) as u64 + 2;
        wire += resp::bulk_wire_len(b"MGETSUFFIX".len());
        write!(self.writer, "*{n_args}\r\n$10\r\nMGETSUFFIX\r\n")?;
        let mut buf = [0u8; 20];
        for &(seq, off) in chunk {
            let key = fmt_dec(seq, &mut buf);
            wire += resp::bulk_wire_len(key.len());
            write!(self.writer, "${}\r\n", key.len())?;
            self.writer.write_all(key)?;
            self.writer.write_all(b"\r\n")?;
            let off = fmt_dec(off as u64, &mut buf);
            wire += resp::bulk_wire_len(off.len());
            write!(self.writer, "${}\r\n", off.len())?;
            self.writer.write_all(off)?;
            self.writer.write_all(b"\r\n")?;
        }
        self.charge_sent(wire);
        Ok(())
    }

    /// Windowed pipelined `MGETSUFFIX` appending the replies into `out`'s
    /// arena — the zero-copy fetch path. One entry per request in request
    /// order (missing keys as missing entries); requests are (sequence
    /// number, offset) pairs formatted on the fly. Wire bytes in both
    /// directions are identical to [`Client::mgetsuffix_pipelined`] over
    /// the same requests — only the reply's destination changes: socket
    /// buffer → arena in one append per suffix, zero per-suffix `Vec`s.
    ///
    /// On a transport failure the connection is re-established and the
    /// unanswered window replayed (fetches are idempotent); entries a
    /// dying chunk half-decoded into `out` are rolled back to the last
    /// completed chunk's [`SuffixBatch::checkpoint`] first, so replay
    /// cannot duplicate entries or arena bytes. On a final error,
    /// entries already appended to `out` are unspecified; callers
    /// discard the batch.
    pub fn mgetsuffix_pipelined_into(
        &mut self,
        reqs: &[(u64, usize)],
        chunk_pairs: usize,
        out: &mut SuffixBatch,
    ) -> Result<()> {
        if reqs.is_empty() {
            return Ok(());
        }
        self.replaying = false;
        let chunk = chunk_pairs.max(1);
        let n_chunks = reqs.len().div_ceil(chunk);
        let bounds = |i: usize| (i * chunk, ((i + 1) * chunk).min(reqs.len()));
        let mut sent = 0usize;
        let mut done = 0usize;
        let mut charged = 0usize;
        let mut tries = 0u32;
        // rollback point: batch state as of the last completed chunk
        let mut mark = out.checkpoint();
        while done < n_chunks {
            let step = 'step: {
                while sent < n_chunks && sent - done < PIPELINE_WINDOW {
                    let (lo, hi) = bounds(sent);
                    self.replaying = sent < charged;
                    charged = charged.max(sent + 1);
                    let r = self.send_mgetsuffix(&reqs[lo..hi]);
                    self.replaying = false;
                    if let Err(e) = r {
                        break 'step Err(e);
                    }
                    sent += 1;
                }
                if let Err(e) = self.writer.flush() {
                    break 'step Err(e.into());
                }
                let (lo, hi) = bounds(done);
                match resp::read_bulk_array_into(&mut self.reader, &mut self.scratch, out) {
                    Ok(resp::ArrayReply::Appended { n, wire_len }) => {
                        self.bytes_received += wire_len;
                        if n != hi - lo {
                            break 'step Err(KvError::Server(format!(
                                "MGETSUFFIX replied {n} elements for {} requests",
                                hi - lo
                            )));
                        }
                        Ok(())
                    }
                    Ok(resp::ArrayReply::Other(v)) => {
                        self.bytes_received += v.wire_len();
                        if let Value::Error(e) = v {
                            break 'step Err(KvError::Server(e));
                        }
                        break 'step Err(KvError::Unexpected(v));
                    }
                    Err(e) => Err(e.into()),
                }
            };
            match step {
                Ok(()) => {
                    done += 1;
                    mark = out.checkpoint();
                }
                Err(KvError::Io(_)) if tries + 1 < self.cfg.failover_attempts.max(1) => {
                    tries += 1;
                    std::thread::sleep(self.cfg.backoff_delay(tries - 1));
                    out.truncate(mark); // drop the half-decoded chunk
                    self.reconnect()
                        .map_err(|e| ctx(self.addr, "MGETSUFFIX", e))?;
                    sent = done; // replay the unanswered window
                }
                Err(e) => return Err(ctx(self.addr, "MGETSUFFIX", e)),
            }
        }
        Ok(())
    }

    /// The paper's `mgetsuffix`: fetch value[offset..] for many
    /// (key, offset) pairs in one round trip.
    pub fn mgetsuffix(&mut self, reqs: &[(Vec<u8>, usize)]) -> Result<Vec<Option<Vec<u8>>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let offs: Vec<Vec<u8>> = reqs.iter().map(|(_, o)| o.to_string().into_bytes()).collect();
        let mut args: Vec<&[u8]> = Vec::with_capacity(1 + reqs.len() * 2);
        args.push(b"MGETSUFFIX");
        for ((k, _), o) in reqs.iter().zip(&offs) {
            args.push(k);
            args.push(o);
        }
        match self.call(&args)? {
            Value::Array(vs) => vs
                .into_iter()
                .map(|v| match v {
                    Value::Bulk(b) => Ok(Some(b)),
                    Value::Null => Ok(None),
                    v => Err(KvError::Unexpected(v)),
                })
                .collect(),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Number of keys stored.
    pub fn dbsize(&mut self) -> Result<i64> {
        match self.call(&[b"DBSIZE"])? {
            Value::Int(i) => Ok(i),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Memory used by the instance (payload + metadata model).
    pub fn used_memory(&mut self) -> Result<i64> {
        match self.call(&[b"MEMORY"])? {
            Value::Int(i) => Ok(i),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Drop every key.
    pub fn flushdb(&mut self) -> Result<()> {
        match self.call(&[b"FLUSHDB"])? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }
}

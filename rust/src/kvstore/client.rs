//! Blocking client for one KV instance, with pipelining — the Jedis role.
//! Tracks wire bytes in both directions for the network-footprint ledger.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};

use crate::kvstore::resp::{self, Value};

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

#[derive(Debug, thiserror::Error)]
pub enum KvError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("server error: {0}")]
    Server(String),
    #[error("unexpected reply: {0:?}")]
    Unexpected(Value),
}

pub type Result<T> = std::result::Result<T, KvError>;

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let conn = TcpStream::connect(addr)?;
        conn.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(conn.try_clone()?),
            writer: BufWriter::new(conn),
            bytes_sent: 0,
            bytes_received: 0,
        })
    }

    fn send(&mut self, args: &[&[u8]]) -> Result<()> {
        self.bytes_sent += resp::command_wire_len(args);
        resp::write_command(&mut self.writer, args)?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Value> {
        let v = resp::read_value(&mut self.reader)?;
        self.bytes_received += v.wire_len();
        if let Value::Error(e) = v {
            return Err(KvError::Server(e));
        }
        Ok(v)
    }

    fn call(&mut self, args: &[&[u8]]) -> Result<Value> {
        self.send(args)?;
        self.writer.flush()?;
        self.recv()
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&[b"PING"])? {
            Value::Bulk(b) if b == b"PONG" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    pub fn set(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        match self.call(&[b"SET", key, value])? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    pub fn get(&mut self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        match self.call(&[b"GET", key])? {
            Value::Bulk(b) => Ok(Some(b)),
            Value::Null => Ok(None),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Batched SET of many records in one round trip (the paper's
    /// "mappers aggregate the reads assigned to the same Redis instance
    /// and put them at one time").
    pub fn mset(&mut self, pairs: &[(Vec<u8>, Vec<u8>)]) -> Result<()> {
        if pairs.is_empty() {
            return Ok(());
        }
        let mut args: Vec<&[u8]> = Vec::with_capacity(1 + pairs.len() * 2);
        args.push(b"MSET");
        for (k, v) in pairs {
            args.push(k);
            args.push(v);
        }
        match self.call(&args)? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Windowed pipelined `mgetsuffix`: keep a few chunks in flight so
    /// request serialization overlaps server work, but bounded — sending
    /// everything before reading anything fills both directions' socket
    /// buffers and the connection degenerates into lockstep stalls under
    /// concurrency (measured 18× collapse; §Perf iteration 5).
    pub fn mgetsuffix_pipelined(
        &mut self,
        reqs: &[(Vec<u8>, usize)],
        chunk_pairs: usize,
    ) -> Result<Vec<Option<Vec<u8>>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        const WINDOW: usize = 3;
        let chunks: Vec<&[(Vec<u8>, usize)]> = reqs.chunks(chunk_pairs).collect();
        let mut out = Vec::with_capacity(reqs.len());
        let mut sent = 0;
        let mut received = 0;
        while received < chunks.len() {
            while sent < chunks.len() && sent - received < WINDOW {
                let chunk = chunks[sent];
                let offs: Vec<Vec<u8>> =
                    chunk.iter().map(|(_, o)| o.to_string().into_bytes()).collect();
                let mut args: Vec<&[u8]> = Vec::with_capacity(1 + chunk.len() * 2);
                args.push(b"MGETSUFFIX");
                for ((k, _), o) in chunk.iter().zip(&offs) {
                    args.push(k);
                    args.push(o);
                }
                self.send(&args)?;
                sent += 1;
            }
            self.writer.flush()?;
            match self.recv()? {
                Value::Array(vs) => {
                    for v in vs {
                        match v {
                            Value::Bulk(b) => out.push(Some(b)),
                            Value::Null => out.push(None),
                            v => return Err(KvError::Unexpected(v)),
                        }
                    }
                }
                v => return Err(KvError::Unexpected(v)),
            }
            received += 1;
        }
        Ok(out)
    }

    /// The paper's `mgetsuffix`: fetch value[offset..] for many
    /// (key, offset) pairs in one round trip.
    pub fn mgetsuffix(&mut self, reqs: &[(Vec<u8>, usize)]) -> Result<Vec<Option<Vec<u8>>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let offs: Vec<Vec<u8>> = reqs.iter().map(|(_, o)| o.to_string().into_bytes()).collect();
        let mut args: Vec<&[u8]> = Vec::with_capacity(1 + reqs.len() * 2);
        args.push(b"MGETSUFFIX");
        for ((k, _), o) in reqs.iter().zip(&offs) {
            args.push(k);
            args.push(o);
        }
        match self.call(&args)? {
            Value::Array(vs) => vs
                .into_iter()
                .map(|v| match v {
                    Value::Bulk(b) => Ok(Some(b)),
                    Value::Null => Ok(None),
                    v => Err(KvError::Unexpected(v)),
                })
                .collect(),
            v => Err(KvError::Unexpected(v)),
        }
    }

    pub fn dbsize(&mut self) -> Result<i64> {
        match self.call(&[b"DBSIZE"])? {
            Value::Int(i) => Ok(i),
            v => Err(KvError::Unexpected(v)),
        }
    }

    pub fn used_memory(&mut self) -> Result<i64> {
        match self.call(&[b"MEMORY"])? {
            Value::Int(i) => Ok(i),
            v => Err(KvError::Unexpected(v)),
        }
    }

    pub fn flushdb(&mut self) -> Result<()> {
        match self.call(&[b"FLUSHDB"])? {
            Value::Simple(s) if s == "OK" => Ok(()),
            v => Err(KvError::Unexpected(v)),
        }
    }
}

//! Flat suffix-text batches: one contiguous byte arena plus a spans
//! table, in place of `Vec<Vec<u8>>` on the whole fetch path.
//!
//! The paper's own breakdown (§IV-D) puts ~60% of reducer wall time in
//! *getting suffixes*; a heap `Vec<u8>` per suffix at every layer makes
//! that path allocator-bound instead of memory-bandwidth-bound (the
//! lesson of flat string sets in scalable string/suffix sorting —
//! PAPERS.md: Bingmann 2018, KIT distributed-SA 2024). A [`SuffixBatch`]
//! stores every text of one fetch back to back in `data`, with one
//! `(start, len)` span per entry:
//!
//! ```text
//!   data:  [ t e x t 0 | t e x t 1 | t e x t 2 | ... ]      one Vec<u8>
//!   spans: [ (0,5)     , (5,5)     , (10,5)    , ... ]      one Vec<Span>
//! ```
//!
//! Entries are read as borrowed `&[u8]` slices ([`SuffixBatch::slice`]),
//! reordering is a *spans* permutation (the bytes never move), and
//! [`SuffixBatch::clear`] keeps both capacities — so a reused batch does
//! zero allocations in steady state (proved by the counting-allocator
//! test `tests/alloc_count.rs`).
//!
//! Ownership rules (see docs/ARCHITECTURE.md "Zero-copy suffix fetch"):
//! the batch owns its bytes; producers append (RESP decode streams
//! socket bytes straight into the arena, the in-process store copies
//! store slices in), consumers only borrow. A "missing" entry (RESP null
//! bulk) is a sentinel span, distinct from an empty text.

use std::fmt;

/// One entry's location in the arena. `start == usize::MAX` marks a
/// missing entry (RESP `$-1` null bulk — key not in the store).
#[derive(Clone, Copy, Debug)]
struct Span {
    start: usize,
    len: usize,
}

const MISSING: Span = Span { start: usize::MAX, len: 0 };

/// A flat batch of suffix texts: one byte arena + a spans table.
#[derive(Default)]
pub struct SuffixBatch {
    data: Vec<u8>,
    spans: Vec<Span>,
}

impl SuffixBatch {
    /// An empty batch.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty batch with pre-sized spans table and arena.
    pub fn with_capacity(entries: usize, arena_bytes: usize) -> Self {
        Self {
            data: Vec::with_capacity(arena_bytes),
            spans: Vec::with_capacity(entries),
        }
    }

    /// Drop every entry but keep both allocations — the reuse point that
    /// makes steady-state fetches allocation-free.
    pub fn clear(&mut self) {
        self.data.clear();
        self.spans.clear();
    }

    /// Number of entries (missing ones included).
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    /// True when the batch holds no entries.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Bytes currently in the arena.
    pub fn arena_len(&self) -> usize {
        self.data.len()
    }

    /// Entry `i` as a borrowed slice; `None` if it is missing.
    pub fn get(&self, i: usize) -> Option<&[u8]> {
        let s = self.spans[i];
        if s.start == usize::MAX {
            None
        } else {
            Some(&self.data[s.start..s.start + s.len])
        }
    }

    /// Entry `i` as a borrowed slice; panics if it is missing.
    pub fn slice(&self, i: usize) -> &[u8] {
        self.get(i).expect("missing suffix entry")
    }

    /// True when entry `i` is a missing (null) entry.
    pub fn is_missing(&self, i: usize) -> bool {
        self.spans[i].start == usize::MAX
    }

    /// Append one entry by copying `bytes` into the arena.
    pub fn push(&mut self, bytes: &[u8]) {
        let start = self.data.len();
        self.data.extend_from_slice(bytes);
        self.spans.push(Span { start, len: bytes.len() });
    }

    /// Append one missing (null) entry.
    pub fn push_missing(&mut self) {
        self.spans.push(MISSING);
    }

    /// Append `n` missing slots, to be filled out of order by
    /// [`SuffixBatch::fill_slot`]/[`SuffixBatch::set_slot`] — the scatter
    /// step of a sharded fetch, where per-shard replies arrive grouped by
    /// shard but land at their original request positions.
    pub fn reserve_slots(&mut self, n: usize) {
        self.spans.resize(self.spans.len() + n, MISSING);
    }

    /// Fill reserved slot `i` by appending `bytes` to the arena.
    pub fn fill_slot(&mut self, i: usize, bytes: &[u8]) {
        let start = self.data.len();
        self.data.extend_from_slice(bytes);
        self.spans[i] = Span { start, len: bytes.len() };
    }

    /// Point slot `i` at arena range `start..start + len` (already
    /// appended, e.g. via [`SuffixBatch::append_arena`]).
    pub fn set_slot(&mut self, i: usize, start: usize, len: usize) {
        assert!(start + len <= self.data.len(), "span outside the arena");
        self.spans[i] = Span { start, len };
    }

    /// Entry `i`'s `(start, len)` within its arena; `None` if missing.
    pub fn entry_span(&self, i: usize) -> Option<(usize, usize)> {
        let s = self.spans[i];
        if s.start == usize::MAX {
            None
        } else {
            Some((s.start, s.len))
        }
    }

    /// Append `other`'s whole arena (one bulk copy, no per-entry work)
    /// and return the base offset its spans must be rebased by. The
    /// sharded fetch concatenates per-shard arenas this way: one
    /// `memcpy` per *shard*, then a spans permutation per entry.
    pub fn append_arena(&mut self, other: &SuffixBatch) -> usize {
        let base = self.data.len();
        self.data.extend_from_slice(&other.data);
        base
    }

    /// Append raw bytes to the arena without creating an entry —
    /// streaming producers (RESP decode copying straight out of the
    /// socket buffer) append chunks, then call
    /// [`SuffixBatch::seal_entry`] once the entry is complete. This is
    /// append-only: no zero-fill pass over the payload.
    pub fn append_raw(&mut self, bytes: &[u8]) {
        self.data.extend_from_slice(bytes);
    }

    /// Append one entry spanning the last `len` arena bytes.
    pub fn seal_entry(&mut self, len: usize) {
        let start = self.data.len().checked_sub(len).expect("arena underflow");
        self.spans.push(Span { start, len });
    }

    /// Iterate entries in order as `Option<&[u8]>` (missing = `None`).
    pub fn iter(&self) -> impl Iterator<Item = Option<&[u8]>> + '_ {
        (0..self.len()).map(|i| self.get(i))
    }

    /// Snapshot `(entries, arena_bytes)` for [`SuffixBatch::truncate`] —
    /// taken by the pipelined client before decoding each reply chunk so
    /// a chunk that dies mid-decode (shard failover) can be rolled back
    /// and replayed without duplicating entries or arena bytes.
    pub fn checkpoint(&self) -> (usize, usize) {
        (self.spans.len(), self.data.len())
    }

    /// Roll the batch back to a [`SuffixBatch::checkpoint`]: drop every
    /// entry and arena byte appended since. Panics if the mark is ahead
    /// of the current state (it must come from this batch's past).
    pub fn truncate(&mut self, mark: (usize, usize)) {
        let (entries, arena_bytes) = mark;
        assert!(
            entries <= self.spans.len() && arena_bytes <= self.data.len(),
            "truncate mark ahead of batch state"
        );
        self.spans.truncate(entries);
        self.data.truncate(arena_bytes);
    }
}

/// Logical equality: same entries in the same order, regardless of how
/// the arenas are laid out (scatter order differs across shard counts).
impl PartialEq for SuffixBatch {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len() && self.iter().eq(other.iter())
    }
}

impl Eq for SuffixBatch {}

/// Compact Debug: entry count + arena bytes, not megabytes of payload.
impl fmt::Debug for SuffixBatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SuffixBatch")
            .field("entries", &self.len())
            .field("arena_bytes", &self.arena_len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_get_roundtrip() {
        let mut b = SuffixBatch::new();
        b.push(b"ACGT");
        b.push_missing();
        b.push(b"");
        b.push(b"TT");
        assert_eq!(b.len(), 4);
        assert_eq!(b.get(0), Some(&b"ACGT"[..]));
        assert_eq!(b.get(1), None);
        assert!(b.is_missing(1));
        assert_eq!(b.get(2), Some(&b""[..]));
        assert_eq!(b.get(3), Some(&b"TT"[..]));
        assert_eq!(b.arena_len(), 6);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut b = SuffixBatch::new();
        b.push(&[7u8; 1000]);
        let cap = b.data.capacity();
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.arena_len(), 0);
        assert_eq!(b.data.capacity(), cap);
    }

    #[test]
    fn scatter_via_slots() {
        // per-shard arrival order {2, 0} then {1}, request order 0..3
        let mut shard_a = SuffixBatch::new();
        shard_a.push(b"two");
        shard_a.push(b"zero");
        let mut shard_b = SuffixBatch::new();
        shard_b.push(b"one");

        let mut out = SuffixBatch::new();
        out.reserve_slots(3);
        let base = out.append_arena(&shard_a);
        for (j, &pos) in [2usize, 0].iter().enumerate() {
            let (s, l) = shard_a.entry_span(j).unwrap();
            out.set_slot(pos, base + s, l);
        }
        let base = out.append_arena(&shard_b);
        let (s, l) = shard_b.entry_span(0).unwrap();
        out.set_slot(1, base + s, l);

        assert_eq!(out.slice(0), b"zero");
        assert_eq!(out.slice(1), b"one");
        assert_eq!(out.slice(2), b"two");
    }

    #[test]
    fn streaming_arena_ops() {
        // the RESP decode pattern: a payload arrives in chunks (socket
        // buffer refills), appended raw and sealed as one entry
        let mut b = SuffixBatch::new();
        b.append_raw(b"AC");
        b.append_raw(b"GT");
        b.seal_entry(4);
        assert_eq!(b.slice(0), b"ACGT");
        // a second streamed entry lands right behind it
        b.append_raw(b"TT");
        b.seal_entry(2);
        assert_eq!(b.slice(1), b"TT");
        assert_eq!(b.arena_len(), 6);
    }

    #[test]
    fn logical_equality_ignores_layout() {
        let mut a = SuffixBatch::new();
        a.push(b"x");
        a.push(b"yy");
        let mut b = SuffixBatch::new();
        b.reserve_slots(2);
        b.fill_slot(1, b"yy");
        b.fill_slot(0, b"x");
        assert_eq!(a, b);
        b.push_missing();
        assert_ne!(a, b);
    }

    #[test]
    fn checkpoint_truncate_rolls_back_partial_decode() {
        let mut b = SuffixBatch::new();
        b.push(b"kept");
        let mark = b.checkpoint();
        // a partially-decoded reply chunk: raw bytes + some sealed entries
        b.push(b"doomed");
        b.append_raw(b"half-an-ent");
        b.truncate(mark);
        assert_eq!(b.len(), 1);
        assert_eq!(b.slice(0), b"kept");
        assert_eq!(b.arena_len(), 4);
        // replay lands identically
        b.push(b"doomed");
        assert_eq!(b.slice(1), b"doomed");
    }

    #[test]
    #[should_panic(expected = "missing suffix entry")]
    fn slice_panics_on_missing() {
        let mut b = SuffixBatch::new();
        b.push_missing();
        b.slice(0);
    }
}

//! The serving tier: `SEARCH`/`PAIRS`/`STAT` over a sealed index.
//!
//! A [`QueryServer`] is the second dialect plugged into the reusable
//! RESP service layer (`crate::kvstore::service`) — it shares the KV
//! server's accept loop, pipelining-aware flush policy, wire
//! accounting, fault-injection hooks, and shutdown/restart machinery,
//! but serves a different resource: one immutable, checksum-verified
//! [`SealedIndex`] shared by every connection. Because the artifact is
//! read-only, the query path takes **no lock at all** — handlers read
//! the shared `Arc` directly, so concurrent clients scale without the
//! store-mutex serialization the construction-side KV server needs.
//!
//! The wire dialect (all arguments ASCII):
//!
//! * `SEARCH <pattern>` → flat array of integers, `(seq, offset)` per
//!   hit, sorted — `IndexView::find` over the wire.
//! * `PAIRS <fwd> <rev> <max_insert>` → flat array of integers,
//!   `(fragment, fwd_seq, fwd_off, rev_seq, rev_off)` per joined hit —
//!   `IndexView::find_pairs` over the wire.
//! * `STAT` → `[n_suffixes, n_reads, n_files, corpus_bytes, file_bytes,
//!   has_lcp, has_tree, has_bwt]` — counts, the artifact's on-disk size,
//!   and the presence (0/1) of the v2 acceleration sections.
//! * `PING` → `PONG` (health check, same as the KV dialect).
//!
//! Replies carry only integers, so a TCP answer is convertible back to
//! exactly the in-memory answer — the serving equivalence tests assert
//! byte-identical results between the two paths.

use std::io;
use std::net::SocketAddr;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use crate::faults::FaultPlan;
use crate::kvstore::client::{Client, FailoverConfig, KvError, Result};
use crate::kvstore::resp::{self, Value};
use crate::kvstore::service::{RespHandler, RespServer, RespService};
use crate::suffix::encode::strict_code_of;
use crate::suffix::sealed::SealedIndex;
use crate::suffix::search::{IndexView, PairHit};

/// TCP server answering suffix-array queries over one shared read-only
/// [`SealedIndex`].
pub struct QueryServer {
    inner: RespServer,
    index: Arc<SealedIndex>,
    /// Total request wire bytes received (network-footprint accounting).
    pub bytes_in: Arc<AtomicU64>,
    /// Total reply wire bytes sent (network-footprint accounting).
    pub bytes_out: Arc<AtomicU64>,
}

struct QueryService {
    index: Arc<SealedIndex>,
}

impl RespService for QueryService {
    fn handler(&self) -> Box<dyn RespHandler> {
        Box::new(QueryHandler { index: self.index.clone() })
    }
}

struct QueryHandler {
    index: Arc<SealedIndex>,
}

/// Decode an ASCII pattern argument into base codes, or a RESP error
/// naming the offending byte. Strict: `N` and anything outside `$ACGT`
/// is rejected, not masked — a query must not silently match the wrong
/// bases.
fn parse_pattern(arg: &[u8]) -> std::result::Result<Vec<u8>, Value> {
    let mut codes = Vec::with_capacity(arg.len());
    for &c in arg {
        match strict_code_of(c) {
            Some(code) => codes.push(code),
            None => {
                return Err(Value::Error(format!(
                    "ERR pattern byte {:?} is not a base (expected one of $ACGT)",
                    c as char
                )))
            }
        }
    }
    Ok(codes)
}

impl QueryHandler {
    fn dispatch(&self, args: &[Vec<u8>]) -> Value {
        let cmd = &args[0];
        if cmd.eq_ignore_ascii_case(b"SEARCH") {
            if args.len() != 2 {
                return Value::Error("ERR SEARCH takes exactly one pattern".into());
            }
            let codes = match parse_pattern(&args[1]) {
                Ok(c) => c,
                Err(e) => return e,
            };
            let hits = self.index.find(&codes);
            let mut out = Vec::with_capacity(hits.len() * 2);
            for (seq, off) in hits {
                out.push(Value::Int(seq as i64));
                out.push(Value::Int(off as i64));
            }
            Value::Array(out)
        } else if cmd.eq_ignore_ascii_case(b"PAIRS") {
            if args.len() != 4 {
                return Value::Error("ERR PAIRS takes <fwd> <rev> <max_insert>".into());
            }
            let (fwd, rev) = match (parse_pattern(&args[1]), parse_pattern(&args[2])) {
                (Ok(f), Ok(r)) => (f, r),
                (Err(e), _) | (_, Err(e)) => return e,
            };
            let Some(max_insert) = std::str::from_utf8(&args[3])
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
            else {
                return Value::Error("ERR bad max-insert (expected a decimal length)".into());
            };
            let hits = self.index.find_pairs(&fwd, &rev, max_insert);
            let mut out = Vec::with_capacity(hits.len() * 5);
            for h in hits {
                out.push(Value::Int(h.fragment as i64));
                out.push(Value::Int(h.forward.0 as i64));
                out.push(Value::Int(h.forward.1 as i64));
                out.push(Value::Int(h.reverse.0 as i64));
                out.push(Value::Int(h.reverse.1 as i64));
            }
            Value::Array(out)
        } else if cmd.eq_ignore_ascii_case(b"STAT") {
            let st = self.index.stats();
            Value::Array(vec![
                Value::Int(st.n_suffixes as i64),
                Value::Int(st.n_reads as i64),
                Value::Int(st.n_files as i64),
                Value::Int(st.corpus_bytes as i64),
                Value::Int(st.file_bytes as i64),
                Value::Int(st.has_lcp as i64),
                Value::Int(st.has_tree as i64),
                Value::Int(st.has_bwt as i64),
            ])
        } else if cmd.eq_ignore_ascii_case(b"PING") {
            Value::Bulk(b"PONG".to_vec())
        } else {
            Value::Error(format!(
                "ERR unknown query command {:?}",
                String::from_utf8_lossy(cmd)
            ))
        }
    }
}

impl RespHandler for QueryHandler {
    fn handle(&mut self, args: &[Vec<u8>], reply: &mut Vec<u8>) -> io::Result<u64> {
        let v = self.dispatch(args);
        resp::write_value(reply, &v)?;
        Ok(v.wire_len())
    }
}

impl QueryServer {
    /// Bind `127.0.0.1:port` (port 0 = ephemeral) and serve queries over
    /// `index`.
    pub fn start(port: u16, index: Arc<SealedIndex>) -> io::Result<QueryServer> {
        Self::start_with_faults(port, 0, None, index)
    }

    /// [`QueryServer::start`] with a fault-injection plan: this instance
    /// is shard `shard` of the plan — the same kill/revive schedule and
    /// reply-delay hooks the KV server honors.
    pub fn start_with_faults(
        port: u16,
        shard: usize,
        faults: Option<Arc<FaultPlan>>,
        index: Arc<SealedIndex>,
    ) -> io::Result<QueryServer> {
        let inner = RespServer::start(
            port,
            shard,
            faults,
            Arc::new(QueryService { index: index.clone() }),
        )?;
        Ok(QueryServer {
            bytes_in: inner.bytes_in.clone(),
            bytes_out: inner.bytes_out.clone(),
            index,
            inner,
        })
    }

    /// Revive a shut-down query server on the same address over the same
    /// sealed index. A no-op while running.
    pub fn restart(&mut self) -> io::Result<()> {
        self.inner.restart()
    }

    /// The bound listen address.
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr()
    }

    /// The served artifact (shared, immutable).
    pub fn index(&self) -> &Arc<SealedIndex> {
        &self.index
    }

    /// Connection handles the accept loop currently tracks.
    pub fn tracked_connections(&self) -> usize {
        self.inner.tracked_connections()
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(&mut self) {
        self.inner.shutdown()
    }
}

/// Headline counts of a served index, as answered by `STAT`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueryStat {
    /// Suffix-array entries served.
    pub n_suffixes: u64,
    /// Reads in the served corpus.
    pub n_reads: u64,
    /// Input files the construction consumed.
    pub n_files: u64,
    /// Corpus payload bytes.
    pub corpus_bytes: u64,
    /// On-disk size of the whole sealed artifact.
    pub file_bytes: u64,
    /// Whether the artifact carries an LCP section.
    pub has_lcp: bool,
    /// Whether the artifact carries a midpoint-tree section
    /// (accelerated `SEARCH` in effect).
    pub has_tree: bool,
    /// Whether the artifact carries a BWT section.
    pub has_bwt: bool,
}

/// Client for the query dialect: the KV [`Client`]'s transport
/// (pipelining, bounded reconnect/backoff failover, wire accounting)
/// speaking `SEARCH`/`PAIRS`/`STAT`. Queries are read-only and therefore
/// idempotent, so the inherited replay-on-reconnect failover is sound
/// here too.
pub struct QueryClient {
    c: Client,
}

fn expect_int(v: Value) -> Result<i64> {
    match v {
        Value::Int(i) => Ok(i),
        v => Err(KvError::Unexpected(v)),
    }
}

impl QueryClient {
    /// Connect with default failover policy.
    pub fn connect(addr: SocketAddr) -> Result<QueryClient> {
        Ok(QueryClient { c: Client::connect(addr)? })
    }

    /// Connect with an explicit failover policy.
    pub fn connect_with(addr: SocketAddr, cfg: FailoverConfig) -> Result<QueryClient> {
        Ok(QueryClient { c: Client::connect_with(addr, cfg)? })
    }

    /// Health check.
    pub fn ping(&mut self) -> Result<()> {
        self.c.ping()
    }

    /// Logical wire traffic so far: (sent, received) bytes.
    pub fn traffic(&self) -> (u64, u64) {
        (self.c.bytes_sent, self.c.bytes_received)
    }

    /// All occurrences of the ASCII `pattern`, as sorted `(seq, offset)`
    /// pairs — the TCP twin of `IndexView::find`.
    pub fn search(&mut self, pattern: &[u8]) -> Result<Vec<(u64, usize)>> {
        match self.c.call(&[b"SEARCH", pattern])? {
            Value::Array(vs) => {
                if vs.len() % 2 != 0 {
                    return Err(KvError::Server(format!(
                        "SEARCH replied {} integers; (seq, offset) pairs expected",
                        vs.len()
                    )));
                }
                let mut out = Vec::with_capacity(vs.len() / 2);
                let mut it = vs.into_iter();
                while let (Some(seq), Some(off)) = (it.next(), it.next()) {
                    out.push((expect_int(seq)? as u64, expect_int(off)? as usize));
                }
                Ok(out)
            }
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Pair-end seed query over the wire — the TCP twin of
    /// `IndexView::find_pairs`. Seeds are ASCII; `seed_rev` is in the
    /// reverse read's coordinates, as in the in-memory query.
    pub fn pairs(
        &mut self,
        seed_fwd: &[u8],
        seed_rev: &[u8],
        max_insert: usize,
    ) -> Result<Vec<PairHit>> {
        let mi = max_insert.to_string();
        match self.c.call(&[b"PAIRS", seed_fwd, seed_rev, mi.as_bytes()])? {
            Value::Array(vs) => {
                if vs.len() % 5 != 0 {
                    return Err(KvError::Server(format!(
                        "PAIRS replied {} integers; 5-tuples expected",
                        vs.len()
                    )));
                }
                let mut out = Vec::with_capacity(vs.len() / 5);
                let mut it = vs.into_iter();
                while let Some(fragment) = it.next() {
                    let (Some(fs), Some(fo), Some(rs), Some(ro)) =
                        (it.next(), it.next(), it.next(), it.next())
                    else {
                        unreachable!("length checked to be a multiple of 5");
                    };
                    out.push(PairHit {
                        fragment: expect_int(fragment)? as u64,
                        forward: (expect_int(fs)? as u64, expect_int(fo)? as usize),
                        reverse: (expect_int(rs)? as u64, expect_int(ro)? as usize),
                    });
                }
                Ok(out)
            }
            v => Err(KvError::Unexpected(v)),
        }
    }

    /// Headline counts of the served index.
    pub fn stat(&mut self) -> Result<QueryStat> {
        match self.c.call(&[b"STAT"])? {
            Value::Array(vs) if vs.len() == 8 => {
                let mut it = vs.into_iter();
                let mut next = || expect_int(it.next().expect("8 elements")).map(|i| i as u64);
                Ok(QueryStat {
                    n_suffixes: next()?,
                    n_reads: next()?,
                    n_files: next()?,
                    corpus_bytes: next()?,
                    file_bytes: next()?,
                    has_lcp: next()? != 0,
                    has_tree: next()? != 0,
                    has_bwt: next()? != 0,
                })
            }
            v => Err(KvError::Unexpected(v)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::reads::{synth_corpus, CorpusSpec};
    use crate::suffix::sealed::seal;
    use crate::suffix::validate::reference_order;
    use std::time::Duration;

    /// Seal a small repetitive corpus into a temp artifact and open it.
    fn sealed_fixture(name: &str) -> Arc<SealedIndex> {
        let reads = synth_corpus(&CorpusSpec {
            n_reads: 24,
            read_len: 18,
            genome_len: 512, // repetitive: patterns hit many suffixes
            seed: 0x51AB,
            ..Default::default()
        });
        let order = reference_order(&reads);
        let dir = std::env::temp_dir().join(format!("samr-query-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("temp dir");
        let path = dir.join(name);
        seal(&path, &[&reads], &order).expect("seal fixture");
        Arc::new(SealedIndex::open(&path).expect("open fixture"))
    }

    /// A query client outlives a server outage: queries are idempotent,
    /// so the transport's reconnect/replay failover turns a
    /// shutdown+restart into a retried command — same answers, and the
    /// logical wire accounting stays byte-identical to an uninterrupted
    /// session (the replayed sends land in `wasted_sent`).
    #[test]
    fn client_survives_server_restart() {
        let mut server =
            QueryServer::start(0, sealed_fixture("restart.samr")).expect("query server");
        let cfg = FailoverConfig {
            backoff_base: Duration::from_millis(2),
            backoff_cap: Duration::from_millis(20),
            ..FailoverConfig::default()
        };
        let mut c = QueryClient::connect_with(server.addr(), cfg).expect("connect");

        let hits = c.search(b"ACG").expect("search before the outage");
        let stat = c.stat().expect("stat before the outage");
        let (sent_once, recv_once) = c.traffic();
        assert!(sent_once > 0 && recv_once > 0);

        server.shutdown();
        server.restart().expect("restart");

        // same client handle, no caller-side reconnect: the failover
        // inside the transport discovers the dead socket, redials, and
        // replays the command against the revived server
        assert_eq!(c.search(b"ACG").expect("search after restart"), hits);
        assert_eq!(c.stat().expect("stat after restart"), stat);

        let (sent, recv) = c.traffic();
        assert_eq!(
            sent,
            sent_once * 2,
            "logical request bytes: each command charged exactly once"
        );
        assert_eq!(
            recv,
            recv_once * 2,
            "logical reply bytes: each complete reply charged exactly once"
        );
        assert!(
            c.c.wasted_sent > 0,
            "the replay across the outage must be tallied as waste"
        );
    }

    /// `shutdown()` is bounded even while clients hold open connections:
    /// the accept loop actively closes live sockets before joining the
    /// per-connection workers, so an idle client cannot pin it.
    #[test]
    fn shutdown_does_not_wait_for_idle_clients() {
        let mut server =
            QueryServer::start(0, sealed_fixture("bounded.samr")).expect("query server");
        let mut c = QueryClient::connect(server.addr()).expect("connect");
        c.ping().expect("ping");
        // the client stays connected and silent across the shutdown
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "shutdown must not block on a connected-but-idle client"
        );
        assert_eq!(server.tracked_connections(), 0, "workers joined");
    }
}

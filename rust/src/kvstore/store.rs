//! The in-memory store behind one KV instance: a hash map with the
//! paper-calibrated memory accounting and the `MGETSUFFIX` suffix
//! extraction (§IV-B — the command the authors added to Redis so reducers
//! fetch *suffixes*, not whole reads, halving network bytes).

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{self, BufReader, BufWriter, Write};
use std::path::Path;

use crate::kvstore::resp;

/// Per-entry metadata overhead. Calibrated so a ~208-byte read record
/// costs ~1.5× its payload, matching the paper's "about 1.5 times as much
/// space as the input size due to the metadata" (§IV-D).
pub const META_OVERHEAD_PER_ENTRY: u64 = 104;

/// Result of one command dispatch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reply {
    /// Simple-string `+OK`.
    Ok,
    /// Integer reply.
    Int(i64),
    /// Bulk-string reply.
    Bulk(Vec<u8>),
    /// Null bulk (missing key).
    Null,
    /// Array of optional bulks (`MGET` / `MGETSUFFIX`).
    Multi(Vec<Option<Vec<u8>>>),
    /// Error reply.
    Err(String),
}

/// In-memory key-value store with byte accounting.
#[derive(Default)]
pub struct Store {
    map: HashMap<Vec<u8>, Vec<u8>>,
    payload_bytes: u64,
    /// Append-only command log: every successfully dispatched *mutating*
    /// command (SET/MSET/DEL/FLUSHDB) is appended in RESP wire form, so
    /// a killed shard process can be respawned with its data intact.
    /// `None` (the default) = no durability, exactly the old behavior.
    aof: Option<BufWriter<File>>,
}

impl Store {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a store backed by the append-only log at `path`: replay any
    /// commands already in the log (what a shard process killed
    /// mid-job left behind), then keep appending new mutations to it.
    ///
    /// A *truncated* final command — possible when the previous process
    /// died mid-append — ends the replay cleanly: the log is an intent
    /// journal, and a command whose reply never reached the client is
    /// replayed by the client's own idempotent-window failover anyway.
    /// Structurally invalid commands (not mere truncation) are a real
    /// `InvalidData` error.
    pub fn open_aof(path: &Path) -> io::Result<Store> {
        let mut store = Store::new();
        if path.exists() {
            let mut r = BufReader::new(File::open(path)?);
            loop {
                match resp::read_command(&mut r) {
                    Ok(Some(args)) => {
                        if let Reply::Err(e) = store.dispatch(&args) {
                            return Err(io::Error::new(
                                io::ErrorKind::InvalidData,
                                format!("AOF replay rejected a logged command: {e}"),
                            ));
                        }
                    }
                    Ok(None) => break,
                    Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => break,
                    Err(e) => return Err(e),
                }
            }
        }
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        store.aof = Some(BufWriter::new(f));
        Ok(store)
    }

    /// Append one successfully executed mutating command to the log.
    /// `write` + `flush` land the bytes in the kernel page cache, which
    /// survives a killed *process* — the crash model here — so there is
    /// no fsync on the hot path.
    fn log_mutation(&mut self, args: &[Vec<u8>]) {
        if let Some(w) = self.aof.as_mut() {
            let refs: Vec<&[u8]> = args.iter().map(Vec::as_slice).collect();
            if resp::write_command(w, &refs).and_then(|()| w.flush()).is_err() {
                // a log that can no longer be appended to must not keep
                // masquerading as durable — drop it; serving continues
                self.aof = None;
            }
        }
    }

    /// Insert/overwrite, maintaining payload accounting.
    pub fn set_exact(&mut self, key: Vec<u8>, value: Vec<u8>) {
        let klen = key.len() as u64;
        let vlen = value.len() as u64;
        match self.map.insert(key, value) {
            Some(old) => {
                self.payload_bytes = self.payload_bytes - old.len() as u64 + vlen;
            }
            None => {
                self.payload_bytes += klen + vlen;
            }
        }
    }

    /// Borrow the value for `key`, if present.
    pub fn get(&self, key: &[u8]) -> Option<&[u8]> {
        self.map.get(key).map(Vec::as_slice)
    }

    /// Remove `key`; true if it existed.
    pub fn del(&mut self, key: &[u8]) -> bool {
        if let Some(old) = self.map.remove(key) {
            self.payload_bytes -= (key.len() + old.len()) as u64;
            true
        } else {
            false
        }
    }

    /// Suffix of the value from `offset` (clamped) — `MGETSUFFIX` core.
    /// Borrowed: the server streams it onto the wire and the in-process
    /// store appends it to a fetch arena, neither copies it first.
    pub fn get_suffix(&self, key: &[u8], offset: usize) -> Option<&[u8]> {
        self.map.get(key).map(|v| &v[offset.min(v.len())..])
    }

    /// Number of keys stored.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no keys are stored.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Drop every key (`FLUSHDB`).
    pub fn flush(&mut self) {
        self.map.clear();
        self.payload_bytes = 0;
    }

    /// Raw payload bytes stored (keys + values).
    pub fn payload_bytes(&self) -> u64 {
        self.payload_bytes
    }

    /// Memory use including per-entry metadata — what a node must donate
    /// (the paper's 1.5× rule).
    pub fn used_memory(&self) -> u64 {
        self.payload_bytes + self.map.len() as u64 * META_OVERHEAD_PER_ENTRY
    }

    /// Dispatch one RESP-style command (argv) against the store. The
    /// command name is matched case-insensitively on the raw bytes — no
    /// per-command uppercased `String` (the old
    /// `from_utf8_lossy(..).to_ascii_uppercase()` was one allocation per
    /// dispatched command). `MGETSUFFIX` replies still materialize
    /// `Vec`s here; the TCP server bypasses this method for that command
    /// and streams the reply straight from [`Store::get_suffix`] slices
    /// (`server.rs::write_mgetsuffix_reply`, byte-identical).
    pub fn dispatch(&mut self, args: &[Vec<u8>]) -> Reply {
        if args.is_empty() {
            return Reply::Err("ERR empty command".into());
        }
        let cmd = args[0].as_slice();
        let is = |name: &[u8]| cmd.eq_ignore_ascii_case(name);
        if is(b"PING") {
            Reply::Bulk(b"PONG".to_vec())
        } else if is(b"SET") && args.len() == 3 {
            self.set_exact(args[1].clone(), args[2].clone());
            self.log_mutation(args);
            Reply::Ok
        } else if is(b"GET") && args.len() == 2 {
            match self.get(&args[1]) {
                Some(v) => Reply::Bulk(v.to_vec()),
                None => Reply::Null,
            }
        } else if is(b"DEL") && args.len() >= 2 {
            let n = args[1..].iter().filter(|k| self.del(k)).count();
            self.log_mutation(args);
            Reply::Int(n as i64)
        } else if is(b"MSET") && args.len() >= 3 && args.len() % 2 == 1 {
            for kv in args[1..].chunks(2) {
                self.set_exact(kv[0].clone(), kv[1].clone());
            }
            self.log_mutation(args);
            Reply::Ok
        } else if is(b"MGET") && args.len() >= 2 {
            Reply::Multi(args[1..].iter().map(|k| self.get(k).map(<[u8]>::to_vec)).collect())
        } else if is(b"MGETSUFFIX") && args.len() >= 3 && args.len() % 2 == 1 {
            // MGETSUFFIX key off [key off ...] — the paper's added command.
            let mut out = Vec::with_capacity((args.len() - 1) / 2);
            for kv in args[1..].chunks(2) {
                let off: usize = match parse_offset(&kv[1]) {
                    Some(o) => o,
                    None => return Reply::Err("ERR bad offset".into()),
                };
                out.push(self.get_suffix(&kv[0], off).map(<[u8]>::to_vec));
            }
            Reply::Multi(out)
        } else if is(b"DBSIZE") {
            Reply::Int(self.len() as i64)
        } else if is(b"MEMORY") {
            Reply::Int(self.used_memory() as i64)
        } else if is(b"FLUSHDB") {
            self.flush();
            self.log_mutation(args);
            Reply::Ok
        } else {
            let cmd = String::from_utf8_lossy(cmd).to_ascii_uppercase();
            Reply::Err(format!("ERR unknown or malformed command '{cmd}'"))
        }
    }
}

/// Parse an `MGETSUFFIX` offset argument (decimal ASCII), shared by
/// [`Store::dispatch`] and the server's streaming reply path so the two
/// can never disagree on what a valid offset is.
pub fn parse_offset(bytes: &[u8]) -> Option<usize> {
    std::str::from_utf8(bytes).ok().and_then(|s| s.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_del() {
        let mut s = Store::new();
        s.set_exact(b"k".to_vec(), b"value".to_vec());
        assert_eq!(s.get(b"k"), Some(&b"value"[..]));
        assert!(s.del(b"k"));
        assert!(!s.del(b"k"));
        assert_eq!(s.get(b"k"), None);
    }

    #[test]
    fn memory_accounting() {
        let mut s = Store::new();
        s.set_exact(b"a".to_vec(), vec![0u8; 9]);
        assert_eq!(s.payload_bytes(), 10);
        s.set_exact(b"a".to_vec(), vec![0u8; 19]); // overwrite
        assert_eq!(s.payload_bytes(), 20);
        s.set_exact(b"bb".to_vec(), vec![0u8; 8]);
        assert_eq!(s.payload_bytes(), 30);
        assert_eq!(s.used_memory(), 30 + 2 * META_OVERHEAD_PER_ENTRY);
        s.del(b"a");
        assert_eq!(s.payload_bytes(), 10);
        s.flush();
        assert_eq!(s.payload_bytes(), 0);
    }

    #[test]
    fn overhead_is_about_1_5x_for_read_records() {
        // paper §IV-D: 32 GB of input needs ~48 GB of Redis memory.
        let mut s = Store::new();
        for i in 0..100u64 {
            s.set_exact(i.to_be_bytes().to_vec(), vec![1u8; 200]);
        }
        let ratio = s.used_memory() as f64 / s.payload_bytes() as f64;
        assert!((1.4..1.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn mgetsuffix_dispatch() {
        let mut s = Store::new();
        s.set_exact(b"5".to_vec(), b"ACGTACGT".to_vec());
        let r = s.dispatch(&[
            b"MGETSUFFIX".to_vec(),
            b"5".to_vec(),
            b"3".to_vec(),
            b"5".to_vec(),
            b"8".to_vec(),
            b"missing".to_vec(),
            b"0".to_vec(),
        ]);
        assert_eq!(
            r,
            Reply::Multi(vec![
                Some(b"TACGT".to_vec()),
                Some(b"".to_vec()), // offset == len -> empty (the "$" suffix)
                None,
            ])
        );
    }

    fn aof_tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("samr-aoftest-{}-0", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(format!("{tag}.aof"))
    }

    fn dispatch_str(s: &mut Store, args: &[&str]) -> Reply {
        let argv: Vec<Vec<u8>> = args.iter().map(|a| a.as_bytes().to_vec()).collect();
        s.dispatch(&argv)
    }

    #[test]
    fn aof_replays_mutations_across_reopen() {
        let path = aof_tmp("roundtrip");
        std::fs::remove_file(&path).ok();
        {
            let mut s = Store::open_aof(&path).unwrap();
            assert_eq!(dispatch_str(&mut s, &["SET", "a", "1"]), Reply::Ok);
            assert_eq!(dispatch_str(&mut s, &["MSET", "b", "2", "c", "3"]), Reply::Ok);
            assert_eq!(dispatch_str(&mut s, &["DEL", "b"]), Reply::Int(1));
            assert_eq!(dispatch_str(&mut s, &["GET", "a"]), Reply::Bulk(b"1".to_vec()));
            // reads are not logged
        }
        let mut s = Store::open_aof(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(b"a"), Some(&b"1"[..]));
        assert_eq!(s.get(b"b"), None);
        assert_eq!(s.get(b"c"), Some(&b"3"[..]));
        // appends keep working after a replayed open
        assert_eq!(dispatch_str(&mut s, &["SET", "d", "4"]), Reply::Ok);
        drop(s);
        let s = Store::open_aof(&path).unwrap();
        assert_eq!(s.len(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn aof_tolerates_a_truncated_tail() {
        let path = aof_tmp("trunc");
        std::fs::remove_file(&path).ok();
        {
            let mut s = Store::open_aof(&path).unwrap();
            dispatch_str(&mut s, &["SET", "a", "1"]);
            dispatch_str(&mut s, &["SET", "b", "2"]);
        }
        // chop mid-command, as a process killed mid-append would
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);
        let s = Store::open_aof(&path).unwrap();
        assert_eq!(s.get(b"a"), Some(&b"1"[..]));
        assert_eq!(s.get(b"b"), None, "the torn tail command must not half-apply");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flushdb_is_logged() {
        let path = aof_tmp("flush");
        std::fs::remove_file(&path).ok();
        {
            let mut s = Store::open_aof(&path).unwrap();
            dispatch_str(&mut s, &["SET", "a", "1"]);
            dispatch_str(&mut s, &["FLUSHDB"]);
            dispatch_str(&mut s, &["SET", "z", "9"]);
        }
        let s = Store::open_aof(&path).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(b"z"), Some(&b"9"[..]));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn suffix_offset_clamps() {
        let mut s = Store::new();
        s.set_exact(b"k".to_vec(), b"AC".to_vec());
        assert_eq!(s.get_suffix(b"k", 100), Some(&b""[..]));
    }

    #[test]
    fn dispatch_surface() {
        let mut s = Store::new();
        assert_eq!(s.dispatch(&[b"PING".to_vec()]), Reply::Bulk(b"PONG".to_vec()));
        assert_eq!(
            s.dispatch(&[b"SET".to_vec(), b"a".to_vec(), b"1".to_vec()]),
            Reply::Ok
        );
        let mset: Vec<Vec<u8>> =
            [b"MSET" as &[u8], b"b", b"2", b"c", b"3"].iter().map(|a| a.to_vec()).collect();
        assert_eq!(s.dispatch(&mset), Reply::Ok);
        assert_eq!(
            s.dispatch(&[b"MGET".to_vec(), b"a".to_vec(), b"zz".to_vec()]),
            Reply::Multi(vec![Some(b"1".to_vec()), None])
        );
        assert_eq!(s.dispatch(&[b"DBSIZE".to_vec()]), Reply::Int(3));
        // command matching is case-insensitive on the raw bytes (the old
        // uppercased-String dispatch accepted these too)
        assert_eq!(s.dispatch(&[b"ping".to_vec()]), Reply::Bulk(b"PONG".to_vec()));
        assert_eq!(
            s.dispatch(&[b"mGet".to_vec(), b"a".to_vec()]),
            Reply::Multi(vec![Some(b"1".to_vec())])
        );
        assert!(matches!(s.dispatch(&[b"NOPE".to_vec()]), Reply::Err(_)));
        assert_eq!(s.dispatch(&[b"FLUSHDB".to_vec()]), Reply::Ok);
        assert_eq!(s.dispatch(&[b"DBSIZE".to_vec()]), Reply::Int(0));
    }
}

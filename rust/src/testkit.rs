//! Mini property-testing framework (the offline vendor set has no
//! proptest): deterministic seeded generators + a runner that reports the
//! failing seed so any counterexample is reproducible with one constant.

use crate::util::rng::Rng;

/// Run `prop` for `cases` deterministic seeds; panic with the failing
/// seed on the first counterexample.
///
/// ```no_run
/// samr::testkit::property("sum is commutative", 64, |rng| {
///     let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
///     if a + b != b + a {
///         return Err(format!("{a} + {b}"));
///     }
///     Ok(())
/// });
/// ```
pub fn property(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x5A3Du64.wrapping_mul(case + 1) ^ case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with Rng::new({seed:#x})"
            );
        }
    }
}

/// Generator helpers over the in-tree PRNG.
pub mod gen {
    use crate::suffix::reads::Read;
    use crate::util::rng::Rng;

    /// Random DNA codes (1..=4) of length in `[min_len, max_len]`.
    pub fn dna(rng: &mut Rng, min_len: usize, max_len: usize) -> Vec<u8> {
        let len = min_len + rng.below((max_len - min_len + 1) as u64) as usize;
        (0..len).map(|_| 1 + rng.below(4) as u8).collect()
    }

    /// Random corpus with consecutive sequence numbers and possible
    /// duplicate reads (stress for tie-breaking).
    pub fn corpus(rng: &mut Rng, max_reads: usize, max_len: usize) -> Vec<Read> {
        let n = 1 + rng.below(max_reads as u64) as usize;
        let mut reads: Vec<Read> = Vec::with_capacity(n);
        for i in 0..n {
            let codes = if i > 0 && rng.f64() < 0.2 {
                // duplicate a random earlier read (stress tie-breaking)
                reads[rng.below(i as u64) as usize].codes.clone()
            } else {
                dna(rng, 1, max_len)
            };
            reads.push(Read::new(i as u64, codes));
        }
        reads
    }

    /// Sorted random boundaries in the keyspace of `prefix_len`.
    pub fn boundaries(rng: &mut Rng, max_n: usize, prefix_len: usize) -> Vec<i64> {
        let n = rng.below(max_n as u64 + 1) as usize;
        let max = 5i64.pow(prefix_len as u32);
        let mut b: Vec<i64> = (0..n).map(|_| rng.below(max as u64) as i64).collect();
        b.sort_unstable();
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes() {
        property("add commutes", 16, |rng| {
            let (a, b) = (rng.below(100) as i64, rng.below(100) as i64);
            (a + b == b + a).then_some(()).ok_or_else(|| "nope".into())
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with")]
    fn property_reports_seed() {
        property("always fails", 4, |_| Err("boom".into()));
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..50 {
            let d = gen::dna(&mut rng, 2, 9);
            assert!((2..=9).contains(&d.len()));
            assert!(d.iter().all(|&c| (1..=4).contains(&c)));
            let b = gen::boundaries(&mut rng, 8, 13);
            assert!(b.windows(2).all(|w| w[0] <= w[1]));
        }
    }
}

//! Deterministic fault injection — the harness behind `tests/fault_tolerance.rs`.
//!
//! A [`FaultPlan`] is an inert description of failures to inject into one
//! job run: task-attempt panics/errors keyed by `(phase, task, attempt)`,
//! a shard kill/revive schedule keyed by request count, and an optional
//! per-reply delay. It is threaded behind zero-cost hooks: the engine
//! checks `JobConf::faults` (default `None`, so the hot path pays one
//! `Option` test per attempt), and the KV server consults the plan only
//! when started with one. Everything is counter-triggered — nothing
//! depends on wall-clock timing — so a given plan produces the same
//! injected failures on every run.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use crate::util::rng::Rng;

/// Job phase a task fault targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Map tasks.
    Map,
    /// Reduce tasks.
    Reduce,
}

impl Phase {
    /// Lower-case name matching the engine's error strings ("map"/"reduce").
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Map => "map",
            Phase::Reduce => "reduce",
        }
    }
}

/// How an injected task failure surfaces inside the attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskFaultKind {
    /// `panic!` from inside the task closure — exercises the engine's
    /// `catch_unwind` conversion plus retry.
    Panic,
    /// A plain `io::Error` returned by the attempt.
    Error,
}

/// Where within the attempt the fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// Before the task body runs — a cheap failure, nothing charged yet.
    Start,
    /// After the task body completed — the expensive case: a full
    /// attempt's ledger charges and scratch files must be rolled back.
    Finish,
}

/// One injected task-attempt failure.
#[derive(Clone, Copy, Debug)]
pub struct TaskFaultSpec {
    /// Phase of the targeted task.
    pub phase: Phase,
    /// Task id within the phase.
    pub task: usize,
    /// Zero-based attempt number the fault fires on.
    pub attempt: usize,
    /// Panic or error.
    pub kind: TaskFaultKind,
    /// Fire before or after the task body.
    pub point: FaultPoint,
}

/// One injected worker-*process* kill, keyed like a task fault. Unlike
/// [`TaskFaultSpec`] these are consulted only by the cluster driver
/// (and, for [`FaultPoint::Finish`], relayed to the worker child): an
/// in-process engine must never act on them, because "kill the worker"
/// means SIGKILL/abort of a whole OS process.
#[derive(Clone, Copy, Debug)]
pub struct ProcFault {
    /// Phase of the targeted task.
    pub phase: Phase,
    /// Task id within the phase.
    pub task: usize,
    /// Zero-based attempt number the kill fires on.
    pub attempt: usize,
    /// [`FaultPoint::Start`]: the driver SIGKILLs the assigned worker
    /// child *before* dispatching the task (the dispatch then fails on a
    /// dead socket). [`FaultPoint::Finish`]: the worker child runs the
    /// task body to completion, journals its ledger delta, and aborts
    /// itself without replying — the expensive case, a full attempt's
    /// charges become waste.
    pub point: FaultPoint,
}

/// Counter-triggered shard-*process* abort: the shard child counts
/// commands exactly like [`ShardFault`] and `abort(2)`s the whole
/// process on the Nth — the driver must respawn it (replaying its
/// append-only log) and clients must rediscover the new address.
#[derive(Clone, Copy, Debug)]
pub struct ShardProcFault {
    /// Index of the shard process the schedule applies to.
    pub shard: usize,
    /// The Nth command processed by that shard aborts the process
    /// before the command executes.
    pub at_request: u64,
}

/// Counter-triggered shard kill/revive schedule, consulted by the KV
/// server started with this plan.
#[derive(Clone, Copy, Debug)]
pub struct ShardFault {
    /// Index of the shard (server) the schedule applies to.
    pub shard: usize,
    /// The Nth command processed by that shard trips the kill: the
    /// connection drops mid-pipeline and the shard refuses new work.
    pub kill_at_request: u64,
    /// While down, this many fresh connections are accepted and
    /// immediately dropped before the shard revives — forcing the
    /// client through multiple reconnect/backoff cycles.
    pub refuse_connects: u64,
}

/// A seeded, deterministic set of faults for one job run, plus the shared
/// counters that drive the shard kill/revive state machine and the
/// observability tallies.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Task-attempt failures to inject. Each `(phase, task, attempt)`
    /// runs at most once per job, so a spec fires at most once.
    pub task_faults: Vec<TaskFaultSpec>,
    /// Optional shard kill/revive schedule.
    pub shard: Option<ShardFault>,
    /// Optional delay applied by the server before processing each
    /// command (never while holding the store lock). With a short client
    /// read timeout this exercises the timeout→replay path; output and
    /// ledger totals are identical whether or not the timeout fires.
    pub reply_delay: Option<Duration>,
    /// Worker-process kills, consulted only by the cluster driver (see
    /// [`ProcFault`]). Harmless in an in-process run: the engine never
    /// reads them.
    pub proc_faults: Vec<ProcFault>,
    /// Optional shard-process abort schedule, consulted only by the
    /// cluster driver when it spawns shard children.
    pub shard_abort: Option<ShardProcFault>,
    /// When set, a tripped [`ShardFault`] kill aborts the whole server
    /// *process* instead of dropping the connection — how a `samr shard`
    /// child turns the counter machinery into a real process death.
    /// Never set this in an in-process run.
    pub process_kill: bool,
    // ---- runtime state (shared via Arc) ----
    requests: AtomicU64,
    down: AtomicBool,
    rejected: AtomicU64,
    // ---- observability ----
    task_faults_fired: AtomicUsize,
    shard_kills: AtomicUsize,
    proc_kills: AtomicUsize,
}

impl FaultPlan {
    /// Plan with explicit task faults and no shard schedule.
    pub fn with_task_faults(task_faults: Vec<TaskFaultSpec>) -> FaultPlan {
        FaultPlan {
            task_faults,
            ..FaultPlan::default()
        }
    }

    /// Plan with only a shard kill/revive schedule.
    pub fn with_shard_fault(shard: ShardFault) -> FaultPlan {
        FaultPlan {
            shard: Some(shard),
            ..FaultPlan::default()
        }
    }

    /// Plan whose only effect is delaying every reply by `delay` — a
    /// pure slow-server plan for timing-sensitive tests.
    pub fn with_reply_delay(delay: std::time::Duration) -> FaultPlan {
        FaultPlan {
            reply_delay: Some(delay),
            ..FaultPlan::default()
        }
    }

    /// Deterministically derive a plan from a seed: one seed-chosen map
    /// task and one reduce task each get a *failure chain* — faults on
    /// attempts `0..=depth` with `depth < max_attempts - 1`, each with a
    /// seed-chosen kind and point. Chains matter: an attempt `k` only
    /// runs after attempts `0..k` failed, so a lone fault at attempt 1
    /// would never fire. Every spec in a seeded plan is reachable, and
    /// the retry budget always absorbs the whole chain.
    pub fn seeded(seed: u64, n_maps: usize, n_reduces: usize, max_attempts: usize) -> FaultPlan {
        assert!(max_attempts >= 2, "a seeded plan needs at least one retry");
        let mut rng = Rng::new(seed);
        let mut faults = Vec::new();
        let mut chain = |phase: Phase, n_tasks: usize, rng: &mut Rng| {
            let task = rng.below(n_tasks.max(1) as u64) as usize;
            let depth = rng.below((max_attempts - 1) as u64) as usize;
            for attempt in 0..=depth {
                faults.push(TaskFaultSpec {
                    phase,
                    task,
                    attempt,
                    kind: if rng.below(2) == 0 {
                        TaskFaultKind::Panic
                    } else {
                        TaskFaultKind::Error
                    },
                    point: if rng.below(2) == 0 {
                        FaultPoint::Start
                    } else {
                        FaultPoint::Finish
                    },
                });
            }
        };
        chain(Phase::Map, n_maps, &mut rng);
        chain(Phase::Reduce, n_reduces, &mut rng);
        FaultPlan::with_task_faults(faults)
    }

    /// Deterministically derive a *process-level* plan from a seed: one
    /// seed-chosen map task gets a worker-kill chain whose first kill is
    /// at [`FaultPoint::Start`] (driver-side SIGKILL before dispatch),
    /// one seed-chosen reduce task gets a chain whose first kill is at
    /// [`FaultPoint::Finish`] (worker self-abort after the task body) —
    /// so every seed exercises both kill paths — and one seed-chosen
    /// shard process aborts mid-put-phase. Chain depths stay under
    /// `max_attempts - 1`, so the retry budget always absorbs them.
    pub fn seeded_process(
        seed: u64,
        n_maps: usize,
        n_reduces: usize,
        max_attempts: usize,
        n_shards: usize,
    ) -> FaultPlan {
        assert!(max_attempts >= 2, "a seeded plan needs at least one retry");
        let mut rng = Rng::new(seed);
        let mut kills = Vec::new();
        let mut chain = |phase: Phase, n_tasks: usize, first: FaultPoint, rng: &mut Rng| {
            let task = rng.below(n_tasks.max(1) as u64) as usize;
            let depth = rng.below((max_attempts - 1) as u64) as usize;
            for attempt in 0..=depth {
                kills.push(ProcFault {
                    phase,
                    task,
                    attempt,
                    point: if attempt == 0 {
                        first
                    } else if rng.below(2) == 0 {
                        FaultPoint::Start
                    } else {
                        FaultPoint::Finish
                    },
                });
            }
        };
        chain(Phase::Map, n_maps, FaultPoint::Start, &mut rng);
        chain(Phase::Reduce, n_reduces, FaultPoint::Finish, &mut rng);
        let shard_abort = Some(ShardProcFault {
            shard: rng.below(n_shards.max(1) as u64) as usize,
            // Low enough to land inside the map phase's puts even for
            // tiny corpora (each put batch is one pipelined command).
            at_request: 2 + rng.below(6),
        });
        FaultPlan {
            proc_faults: kills,
            shard_abort,
            ..FaultPlan::default()
        }
    }

    /// Seed for seeded plans: `SAMR_FAULT_SEED` if set (CI pins it),
    /// otherwise `default`. Sweep seeds locally with e.g.
    /// `for s in $(seq 0 31); do SAMR_FAULT_SEED=$s cargo test --test fault_tolerance; done`.
    pub fn env_seed(default: u64) -> u64 {
        std::env::var("SAMR_FAULT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Engine hook: fail the current attempt if a spec matches. Panics
    /// for [`TaskFaultKind::Panic`], returns `Err` for
    /// [`TaskFaultKind::Error`]; `Ok(())` when nothing matches.
    pub fn maybe_fail(
        &self,
        phase: Phase,
        task: usize,
        attempt: usize,
        point: FaultPoint,
    ) -> std::io::Result<()> {
        for f in &self.task_faults {
            if f.phase == phase && f.task == task && f.attempt == attempt && f.point == point {
                self.task_faults_fired.fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "injected {:?} fault: {} task {task} attempt {attempt} at {point:?}",
                    f.kind,
                    phase.name(),
                );
                match f.kind {
                    TaskFaultKind::Panic => panic!("{msg}"),
                    TaskFaultKind::Error => return Err(std::io::Error::other(msg)),
                }
            }
        }
        Ok(())
    }

    /// Server hook, called once per command processed by shard `shard`.
    /// Returns `true` when the connection must drop *now* — either the
    /// request counter just hit the kill trigger, or the shard is down.
    pub fn on_request(&self, shard: usize) -> bool {
        let Some(sf) = self.shard else { return false };
        if sf.shard != shard {
            return false;
        }
        let n = self.requests.fetch_add(1, Ordering::Relaxed);
        if n == sf.kill_at_request {
            self.down.store(true, Ordering::SeqCst);
            self.shard_kills.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        self.down.load(Ordering::SeqCst)
    }

    /// Server hook, called for each freshly accepted connection on shard
    /// `shard`. Returns `true` when the connection must be refused (the
    /// shard is down). Each refusal counts toward the revive trigger;
    /// once `refuse_connects` connections have been turned away the
    /// shard comes back up.
    pub fn on_connect(&self, shard: usize) -> bool {
        let Some(sf) = self.shard else { return false };
        if sf.shard != shard || !self.down.load(Ordering::SeqCst) {
            return false;
        }
        let r = self.rejected.fetch_add(1, Ordering::SeqCst);
        if r + 1 >= sf.refuse_connects {
            self.down.store(false, Ordering::SeqCst);
        }
        true
    }

    /// Driver hook: does a worker-process kill target this attempt?
    /// Pure lookup — the driver performs the kill (or relays a
    /// [`FaultPoint::Finish`] kill to the worker) and records it with
    /// [`FaultPlan::note_proc_kill`].
    pub fn proc_fault_at(&self, phase: Phase, task: usize, attempt: usize) -> Option<FaultPoint> {
        self.proc_faults
            .iter()
            .find(|f| f.phase == phase && f.task == task && f.attempt == attempt)
            .map(|f| f.point)
    }

    /// Record one worker-process kill actually performed/observed.
    pub fn note_proc_kill(&self) {
        self.proc_kills.fetch_add(1, Ordering::Relaxed);
    }

    /// How many task-attempt faults have fired so far.
    pub fn task_faults_fired(&self) -> usize {
        self.task_faults_fired.load(Ordering::Relaxed)
    }

    /// How many shard kills have fired so far.
    pub fn shard_kills(&self) -> usize {
        self.shard_kills.load(Ordering::Relaxed)
    }

    /// How many worker-process kills have been recorded so far.
    pub fn proc_kills(&self) -> usize {
        self.proc_kills.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_fault_fires_on_exact_coordinates_only() {
        let plan = FaultPlan::with_task_faults(vec![TaskFaultSpec {
            phase: Phase::Map,
            task: 2,
            attempt: 0,
            kind: TaskFaultKind::Error,
            point: FaultPoint::Start,
        }]);
        assert!(plan.maybe_fail(Phase::Map, 1, 0, FaultPoint::Start).is_ok());
        assert!(plan.maybe_fail(Phase::Map, 2, 1, FaultPoint::Start).is_ok());
        assert!(plan.maybe_fail(Phase::Reduce, 2, 0, FaultPoint::Start).is_ok());
        assert!(plan.maybe_fail(Phase::Map, 2, 0, FaultPoint::Finish).is_ok());
        let err = plan
            .maybe_fail(Phase::Map, 2, 0, FaultPoint::Start)
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("injected"), "{msg}");
        assert!(msg.contains("map task 2"), "{msg}");
        assert_eq!(plan.task_faults_fired(), 1);
    }

    #[test]
    fn panic_kind_panics() {
        let plan = FaultPlan::with_task_faults(vec![TaskFaultSpec {
            phase: Phase::Reduce,
            task: 0,
            attempt: 1,
            kind: TaskFaultKind::Panic,
            point: FaultPoint::Finish,
        }]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.maybe_fail(Phase::Reduce, 0, 1, FaultPoint::Finish)
        }));
        assert!(r.is_err());
        assert_eq!(plan.task_faults_fired(), 1);
    }

    #[test]
    fn seeded_plans_are_deterministic_and_retryable() {
        let a = FaultPlan::seeded(42, 4, 2, 3);
        let b = FaultPlan::seeded(42, 4, 2, 3);
        assert_eq!(a.task_faults.len(), b.task_faults.len());
        for (x, y) in a.task_faults.iter().zip(&b.task_faults) {
            assert_eq!(x.phase, y.phase);
            assert_eq!(x.task, y.task);
            assert_eq!(x.attempt, y.attempt);
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.point, y.point);
        }
        for seed in 0..64 {
            let p = FaultPlan::seeded(seed, 4, 2, 3);
            // one chain per phase, each 1..=max_attempts-1 faults long
            assert!((2..=4).contains(&p.task_faults.len()), "seed {seed}");
            for phase in [Phase::Map, Phase::Reduce] {
                let chain: Vec<_> =
                    p.task_faults.iter().filter(|f| f.phase == phase).collect();
                assert!(!chain.is_empty(), "seed {seed}: no {} chain", phase.name());
                for (i, f) in chain.iter().enumerate() {
                    // contiguous from attempt 0: every spec is reachable
                    // (attempt k runs only after 0..k all failed), and the
                    // last failing attempt leaves budget for a clean one
                    assert_eq!(f.attempt, i, "seed {seed}");
                    assert_eq!(f.task, chain[0].task, "seed {seed}: one task per chain");
                    assert!(f.attempt < 2, "seed {seed}");
                }
            }
        }
    }

    #[test]
    fn shard_state_machine_kills_then_revives() {
        let plan = FaultPlan::with_shard_fault(ShardFault {
            shard: 1,
            kill_at_request: 3,
            refuse_connects: 2,
        });
        // Other shards never trip.
        assert!(!plan.on_request(0));
        assert!(!plan.on_connect(0));
        // Requests 0..3 pass, request 3 kills.
        for _ in 0..3 {
            assert!(!plan.on_request(1));
        }
        assert!(plan.on_request(1));
        assert_eq!(plan.shard_kills(), 1);
        // Down: requests on stale connections drop, connects refused.
        assert!(plan.on_request(1));
        assert!(plan.on_connect(1));
        assert!(plan.on_connect(1)); // second refusal revives
        assert!(!plan.on_connect(1));
        assert!(!plan.on_request(1));
    }

    #[test]
    fn seeded_process_plans_cover_both_kill_points_and_stay_retryable() {
        for seed in 0..64 {
            let p = FaultPlan::seeded_process(seed, 4, 2, 3, 2);
            let map: Vec<_> = p.proc_faults.iter().filter(|f| f.phase == Phase::Map).collect();
            let red: Vec<_> =
                p.proc_faults.iter().filter(|f| f.phase == Phase::Reduce).collect();
            assert!(!map.is_empty() && !red.is_empty(), "seed {seed}");
            assert_eq!(map[0].point, FaultPoint::Start, "seed {seed}");
            assert_eq!(red[0].point, FaultPoint::Finish, "seed {seed}");
            for chain in [&map, &red] {
                for (i, f) in chain.iter().enumerate() {
                    // contiguous from attempt 0 so every kill is reachable
                    // and the budget absorbs the chain
                    assert_eq!(f.attempt, i, "seed {seed}");
                    assert_eq!(f.task, chain[0].task, "seed {seed}");
                    assert!(f.attempt < 2, "seed {seed}");
                }
            }
            let sa = p.shard_abort.expect("seeded process plan aborts a shard");
            assert!(sa.shard < 2, "seed {seed}");
            assert!((2..8).contains(&sa.at_request), "seed {seed}");
        }
        // Determinism.
        let a = FaultPlan::seeded_process(9, 4, 2, 3, 2);
        let b = FaultPlan::seeded_process(9, 4, 2, 3, 2);
        assert_eq!(a.proc_faults.len(), b.proc_faults.len());
        assert_eq!(a.shard_abort.unwrap().at_request, b.shard_abort.unwrap().at_request);
    }

    #[test]
    fn proc_fault_lookup_matches_exact_coordinates() {
        let plan = FaultPlan {
            proc_faults: vec![ProcFault {
                phase: Phase::Map,
                task: 1,
                attempt: 0,
                point: FaultPoint::Finish,
            }],
            ..FaultPlan::default()
        };
        assert_eq!(plan.proc_fault_at(Phase::Map, 1, 0), Some(FaultPoint::Finish));
        assert_eq!(plan.proc_fault_at(Phase::Map, 1, 1), None);
        assert_eq!(plan.proc_fault_at(Phase::Map, 0, 0), None);
        assert_eq!(plan.proc_fault_at(Phase::Reduce, 1, 0), None);
        assert_eq!(plan.proc_kills(), 0);
        plan.note_proc_kill();
        assert_eq!(plan.proc_kills(), 1);
    }
}

//! Quickstart: Table I's SINICA$ suffix array, then a tiny corpus through
//! BOTH pipelines (TeraSort baseline and the paper's scheme), validated
//! against the naive oracle.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::reads::{synth_corpus, CorpusSpec};
use samr::suffix::validate::validate_order;
use samr::suffix::{bwt, sa};
use samr::terasort::{self, TeraSortConfig};
use samr::util::bytes::human;

fn main() {
    // PJRT kernels if artifacts/ was built; transparent fallback if not.
    let pjrt = runtime::init(Some(&runtime::default_artifacts_dir()));

    // ---- Table I: the paper's didactic example ----
    let text = b"SINICA";
    let sa = sa::sais(text);
    println!("Suffix array of SINICA$ (Table I):");
    println!("  SA[0] = {}  $", text.len());
    for (i, &p) in sa.iter().enumerate() {
        let suffix: String = text[p as usize..].iter().map(|&c| c as char).collect();
        println!("  SA[{}] = {}  {}$", i + 1, p, suffix);
    }
    let b = bwt::bwt(b"banana");
    let rendered: String = b.iter().map(|c| c.map(|x| x as char).unwrap_or('$')).collect();
    println!("BWT(banana$) = {rendered}  (derived from the SA, §I)\n");

    // ---- both pipelines on a small synthetic corpus ----
    let reads = synth_corpus(&CorpusSpec { n_reads: 500, read_len: 80, ..Default::default() });
    let conf = JobConf { n_reducers: 4, ..JobConf::scaled_down() };

    // both jobs run out-of-core: splits stream from disk-backed record
    // files and reduce output spools back to disk, so only the bounded
    // engine buffers hold records in memory — gauge it
    samr::mapreduce::resident::reset();

    let ledger = Ledger::new();
    let tera = terasort::run(
        &reads,
        &TeraSortConfig { conf: conf.clone(), ..Default::default() },
        &ledger,
    )
    .expect("terasort");
    validate_order(&reads, &tera.order).expect("TeraSort produced a wrong order");

    let ledger2 = Ledger::new();
    let store = SharedStore::new(4);
    let s = store.clone();
    let res = scheme::run(
        &reads,
        &SchemeConfig {
            conf,
            group_threshold: 20_000,
            samples_per_reducer: 500,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger2,
    )
    .expect("scheme");
    validate_order(&reads, &res.order).expect("scheme produced a wrong order");
    assert_eq!(tera.order, res.order, "both pipelines must agree");

    println!(
        "corpus: {} reads, {} suffixes (PJRT kernels: {})",
        reads.len(),
        res.order.len(),
        if pjrt { "on" } else { "off" }
    );
    println!(
        "TeraSort shuffled {}, scheme shuffled {} — the paper's point in one line:",
        human(ledger.get(Channel::Shuffle)),
        human(ledger2.get(Channel::Shuffle))
    );
    println!("  keep only the raw data in place; shuffle indexes, not suffixes.");
    println!(
        "peak resident shuffle records across both jobs: {} (of {} suffixes sorted — \
         the dataflow is disk-backed end to end)",
        samr::mapreduce::resident::peak(),
        res.order.len()
    );
    println!("both pipelines produced the identical, validated suffix order ✓");
}

//! End-to-end driver on a grouper-like workload — the repository's
//! headline validation run (recorded in EXPERIMENTS.md).
//!
//! Generates a synthetic grouper-style corpus (default 60k reads × 100 bp
//! ≈ 6 MB of raw reads → ~330 MB of virtual suffix volume), then runs the
//! FULL stack with nothing mocked:
//!   * real TCP KV instances (RESP + MGETSUFFIX) on localhost,
//!   * the in-process MapReduce runtime with real spill files,
//!   * PJRT-compiled JAX/Pallas kernels on the map and reduce hot paths,
//! and validates the output order against ground truth, comparing the
//! data-store footprint with the TeraSort baseline on the same corpus.
//!
//!     cargo run --release --example grouper_pipeline [n_reads] [read_len]

use std::sync::Arc;
use std::time::Instant;

use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::SuffixStore;
use samr::kvstore::LocalKvCluster;
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::reads::{materialized_suffix_bytes, synth_corpus, CorpusSpec};
use samr::suffix::validate::validate_order;
use samr::terasort::{self, TeraSortConfig};
use samr::util::bytes::human;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n_reads: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60_000);
    let read_len: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(100);
    let pjrt = runtime::init(Some(&runtime::default_artifacts_dir()));

    println!(
        "== grouper pipeline: {n_reads} reads × ~{read_len} bp (PJRT {}) ==",
        if pjrt { "on" } else { "OFF — run `make artifacts`" }
    );
    let reads = synth_corpus(&CorpusSpec {
        n_reads,
        read_len,
        len_jitter: 4,
        gc_content: 0.42, // grouper-like
        genome_len: 1 << 22,
        seed: 0x6706,
    });
    let n_suffixes: usize = reads.iter().map(|r| r.suffix_count()).sum();
    let input = samr::suffix::reads::corpus_bytes(&reads);
    let virt = materialized_suffix_bytes(&reads);
    println!(
        "input {} -> {} suffixes, {} if materialized (self-expansion ×{:.0})",
        human(input),
        n_suffixes,
        human(virt),
        virt as f64 / input as f64
    );

    // ---- the scheme, on real TCP KV instances ----
    let kv = LocalKvCluster::start(8).expect("start KV instances");
    let addrs = kv.addrs();
    let factory: scheme::StoreFactory = Arc::new(move || {
        Box::new(samr::kvstore::shard::ShardedClient::connect(&addrs).expect("kv connect"))
            as Box<dyn SuffixStore>
    });
    let conf = JobConf {
        n_reducers: 8,
        io_sort_bytes: 1 << 20,
        split_bytes: 1 << 20,
        reducer_heap_bytes: 24 << 20,
        ..JobConf::default()
    };
    let cfg = SchemeConfig {
        conf: conf.clone(),
        group_threshold: 200_000,
        samples_per_reducer: 10_000,
        ..Default::default()
    };
    let ledger = Ledger::new();
    let t0 = Instant::now();
    let res = scheme::run(&reads, &cfg, factory, &ledger).expect("scheme run");
    let scheme_wall = t0.elapsed();
    println!(
        "\nscheme: {} suffixes in {:.1?} ({:.0} suffixes/s)",
        res.order.len(),
        scheme_wall,
        res.order.len() as f64 / scheme_wall.as_secs_f64()
    );
    let (f, s, o) = res.time_split.percentages();
    println!("reducer time split: fetch {f:.0}% / sort {s:.0}% / other {o:.0}%  (paper: 60/13/27)");
    println!(
        "KV memory {} ({:.2}x input — paper: 1.5x)",
        human(res.kv_memory),
        res.kv_memory as f64 / input as f64
    );

    // ---- the baseline on the same corpus ----
    let ledger_t = Ledger::new();
    let t0 = Instant::now();
    let tera = terasort::run(&reads, &TeraSortConfig { conf, ..Default::default() }, &ledger_t)
        .expect("terasort run");
    let tera_wall = t0.elapsed();
    println!("\nterasort: {} suffixes in {:.1?}", tera.order.len(), tera_wall);

    // ---- validation against ground truth ----
    let t0 = Instant::now();
    validate_order(&reads, &res.order).expect("scheme order INVALID");
    validate_order(&reads, &tera.order).expect("terasort order INVALID");
    assert_eq!(res.order, tera.order, "pipelines disagree");
    println!(
        "\nvalidation: both orders correct & identical (checked in {:.1?})",
        t0.elapsed()
    );

    // ---- the paper's headline comparison ----
    let u = |l: &Ledger, ch| l.get(ch) as f64 / virt as f64;
    println!("\ndata store footprint (units of materialized suffix volume):");
    println!("{:<22}{:>10}{:>10}", "", "TeraSort", "Scheme");
    for (name, ch) in [
        ("Map Local Write", Channel::MapLocalWrite),
        ("Map Local Read", Channel::MapLocalRead),
        ("Reduce Local R", Channel::ReduceLocalRead),
        ("Reduce Local W", Channel::ReduceLocalWrite),
        ("Shuffle", Channel::Shuffle),
        ("KV Put", Channel::KvPut),
        ("KV Fetch", Channel::KvFetch),
    ] {
        println!("{:<22}{:>10.3}{:>10.3}", name, u(&ledger_t, ch), u(&ledger, ch));
    }
    let t_disk = ledger_t.snapshot().local_disk_total();
    let s_disk = ledger.snapshot().local_disk_total();
    println!(
        "\nlocal-disk bytes: TeraSort {} vs scheme {} — {:.1}x less (paper's key claim)",
        human(t_disk),
        human(s_disk),
        t_disk as f64 / s_disk as f64
    );
    println!(
        "server-side KV traffic: in {} / out {}",
        human(kv.traffic().0),
        human(kv.traffic().1)
    );
}

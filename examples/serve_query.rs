//! The serving tier end to end: construct a pair-end corpus, seal the
//! output into the versioned on-disk artifact, serve it over TCP, and
//! answer a pair-end seed query from a pipelined RESP client — the full
//! build → seal → serve → query lifecycle in one process.
//!
//!     cargo run --release --example serve_query [n_pairs]

use std::sync::Arc;

use samr::footprint::Ledger;
use samr::kvstore::query::{QueryClient, QueryServer};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::sealed::SealedIndex;
use samr::util::bytes::human;

fn main() {
    let n_pairs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1_000);
    runtime::init(Some(&runtime::default_artifacts_dir()));

    // construct: two files over the same fragments (paper Case 6)
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: n_pairs,
        read_len: 100,
        len_jitter: 4,
        genome_len: 1 << 18,
        seed: 0x5EA1,
        ..Default::default()
    });
    let store = SharedStore::new(4);
    let s = store.clone();
    let ledger = Ledger::new();
    let path = std::env::temp_dir().join(format!("samr-example-{}.samr", std::process::id()));
    let res = scheme::run_files_sealed(
        &[&fwd, &rev],
        &SchemeConfig {
            conf: JobConf {
                n_reducers: 4,
                io_sort_bytes: 256 << 10,
                split_bytes: 256 << 10,
                reducer_heap_bytes: 8 << 20,
                ..JobConf::default()
            },
            group_threshold: 100_000,
            samples_per_reducer: 2_000,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger,
        &path,
    )
    .expect("sealed construction");
    let artifact = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "sealed {} suffixes ({} reads × 2 files) into {} ({})",
        res.n_sealed,
        n_pairs,
        path.display(),
        human(artifact)
    );

    // serve: the artifact loads with zero parse work and is shared
    // read-only across every connection — no lock on the query path
    let index = Arc::new(SealedIndex::open(&path).expect("open sealed index"));
    let mut server = QueryServer::start(0, index).expect("query server");
    println!("serving on {}", server.addr());

    // query: a fragment's own seeds must join back to that fragment
    let probe = n_pairs / 2;
    let seed_fwd = ascii_of(&fwd[probe].codes[..16]);
    let seed_rev = ascii_of(&rev[probe].codes[..16]);
    let mut client = QueryClient::connect(server.addr()).expect("connect");
    let st = client.stat().expect("STAT");
    println!(
        "STAT: {} suffixes, {} reads, {} files, corpus {}",
        st.n_suffixes,
        st.n_reads,
        st.n_files,
        human(st.corpus_bytes)
    );
    let hits = client.pairs(&seed_fwd, &seed_rev, 4 * 100).expect("PAIRS");
    assert!(
        hits.iter().any(|h| h.fragment == probe as u64),
        "planted fragment not recovered over TCP"
    );
    println!("PAIRS: {} joined mate pairing(s) for fragment {probe}'s seeds ✓", hits.len());
    let (sent, recvd) = client.traffic();
    println!("client wire traffic: {} out / {} in", human(sent), human(recvd));

    server.shutdown();
    let _ = std::fs::remove_file(&path);
}

/// Codes back to the ASCII the query dialect speaks.
fn ascii_of(codes: &[u8]) -> Vec<u8> {
    codes.iter().map(|&c| b"$ACGT"[c as usize]).collect()
}

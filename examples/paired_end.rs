//! Pair-end sequencing & alignment prep — the paper's Case 6: two input
//! files (forward + reverse-complement reads of the same fragments) fed
//! through the scheme as one SA construction, without any degradation.
//!
//!     cargo run --release --example paired_end [n_pairs]

use std::sync::Arc;

use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::bwt;
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::validate::{read_map, suffix_codes, validate_order};
use samr::util::bytes::human;

fn main() {
    let n_pairs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5_000);
    runtime::init(Some(&runtime::default_artifacts_dir()));

    // two "files": forward reads (seq 0..n) and reverse reads (seq n..2n)
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: n_pairs,
        read_len: 100,
        len_jitter: 4,
        genome_len: 1 << 20,
        seed: 0xA17E,
        ..Default::default()
    });
    let mut reads = fwd;
    reads.extend(rev);
    println!(
        "pair-end corpus: 2 × {n_pairs} reads = {} records, {}",
        reads.len(),
        human(samr::suffix::reads::corpus_bytes(&reads))
    );

    let store = SharedStore::new(8);
    let s = store.clone();
    let ledger = Ledger::new();
    let res = scheme::run(
        &reads,
        &SchemeConfig {
            conf: JobConf {
                n_reducers: 8,
                io_sort_bytes: 512 << 10,
                split_bytes: 512 << 10,
                reducer_heap_bytes: 16 << 20,
                ..JobConf::default()
            },
            group_threshold: 150_000,
            samples_per_reducer: 5_000,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger,
    )
    .expect("scheme");

    validate_order(&reads, &res.order).expect("pair-end order invalid");
    println!("sorted {} suffixes across both files ✓", res.order.len());
    println!(
        "shuffle {} / KV fetch {} / KV memory {}",
        human(ledger.get(Channel::Shuffle)),
        human(ledger.get(Channel::KvFetch)),
        human(res.kv_memory)
    );

    // derive a BWT from one sampled suffix — the index structure the
    // aligner consumes (§I: BWT "can be derived from the former")
    let map = read_map(&reads);
    let sample = suffix_codes(&map, res.order[reads.len()]);
    let b = bwt::bwt(&sample[..sample.len() - 1]);
    println!("BWT of a sampled suffix ({} chars) derived ✓ — ready for alignment", b.len());
}

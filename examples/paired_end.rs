//! Pair-end sequencing & alignment prep — the paper's Case 6: two input
//! files (forward reads + reverse-complement mates of the same
//! fragments) fed through the scheme as ONE construction over a shared
//! store, without any degradation — then a pair-end seed-alignment query
//! over the joint suffix array.
//!
//!     cargo run --release --example paired_end [n_pairs]

use std::sync::Arc;

use samr::footprint::{Channel, Ledger};
use samr::kvstore::shard::{SharedStore, SuffixStore};
use samr::mapreduce::JobConf;
use samr::runtime;
use samr::scheme::{self, SchemeConfig};
use samr::suffix::bwt;
use samr::suffix::reads::{synth_paired_corpus, CorpusSpec};
use samr::suffix::search::find_pairs;
use samr::suffix::validate::{read_map, suffix_codes, validate_order};
use samr::util::bytes::human;

fn main() {
    let n_pairs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5_000);
    runtime::init(Some(&runtime::default_artifacts_dir()));

    // two files over the SAME fragments: file 1 = forward reads (seq 2f),
    // file 2 = reverse-complement mates (seq 2f+1)
    let (fwd, rev) = synth_paired_corpus(&CorpusSpec {
        n_reads: n_pairs,
        read_len: 100,
        len_jitter: 4,
        genome_len: 1 << 20,
        seed: 0xA17E,
        ..Default::default()
    });
    println!(
        "pair-end corpus: 2 files × {n_pairs} reads = {} records, {}",
        fwd.len() + rev.len(),
        human(samr::suffix::reads::corpus_bytes(&fwd) + samr::suffix::reads::corpus_bytes(&rev))
    );

    let store = SharedStore::new(8);
    let s = store.clone();
    let ledger = Ledger::new();
    let res = scheme::run_files(
        &[&fwd, &rev],
        &SchemeConfig {
            conf: JobConf {
                n_reducers: 8,
                io_sort_bytes: 512 << 10,
                split_bytes: 512 << 10,
                reducer_heap_bytes: 16 << 20,
                ..JobConf::default()
            },
            group_threshold: 150_000,
            samples_per_reducer: 5_000,
            ..Default::default()
        },
        Arc::new(move || Box::new(s.clone()) as Box<dyn SuffixStore>),
        &ledger,
    )
    .expect("scheme");

    // seeds of a known fragment, taken before folding the files together
    let probe = n_pairs as u64 / 2;
    let seed_fwd = fwd[probe as usize].codes[..16].to_vec();
    // a reverse-mate seed, in the reverse read's own coordinates
    let seed_rev = rev[probe as usize].codes[..16].to_vec();
    let mut reads = fwd;
    reads.extend(rev);

    validate_order(&reads, &res.order).expect("pair-end order invalid");
    println!("sorted {} suffixes across both files ✓", res.order.len());
    println!(
        "shuffle {} / KV fetch {} / KV memory {}",
        human(ledger.get(Channel::Shuffle)),
        human(ledger.get(Channel::KvFetch)),
        human(res.kv_memory)
    );

    // pair-end seed alignment over the joint SA: join both mates' hits
    // by fragment id
    let map = read_map(&reads);
    let hits = find_pairs(&res.order, &map, &seed_fwd, &seed_rev, 4 * 100);
    assert!(
        hits.iter().any(|h| h.fragment == probe),
        "planted fragment not recovered"
    );
    println!(
        "find_pairs: {} joined mate pairing(s) for fragment {probe}'s seeds ✓",
        hits.len()
    );
    // derive a BWT from one sampled suffix — the index structure the
    // aligner consumes (§I: BWT "can be derived from the former")
    let sample = suffix_codes(&map, res.order[reads.len()]);
    let b = bwt::bwt(&sample[..sample.len() - 1]);
    println!("BWT of a sampled suffix ({} chars) derived ✓ — ready for alignment", b.len());
}
